//! Fleet-wide copies control plane.
//!
//! [`SwarmRegistry`] is the distributed big sibling of
//! [`crate::tier::registry::CopiesRegistry`]: where that one tracks
//! which *tiers* hold a step on a single cascade, this one tracks
//! every (step, chunk) copy across every node in the fleet, plus
//! whole-step tier copies, so both the swarm scheduler and
//! `TierCascade::restore_via` can ask for the fastest surviving
//! source after failures.
//!
//! Publishes are epoch-gated: a node registers a chunk copy only by
//! presenting the step's commit epoch (the value of the PFS
//! `.ckpt_epoch` marker at commit time). A peer store left over from
//! an earlier run — or one whose storm died before the commit rename —
//! carries a stale or missing epoch and its publishes are rejected, so
//! the registry can never direct a reader at uncommitted bytes.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::{Mutex, MutexGuard};

use crate::tier::Tier;
use crate::util::json::Json;

/// Per-step distribution state.
#[derive(Debug, Default)]
struct StepState {
    /// Commit epoch the step was registered with; publishes must match.
    epoch: String,
    /// One holder set per chunk index.
    holders: Vec<BTreeSet<usize>>,
    /// Whole-step copies by cascade tier (mirrors
    /// [`crate::tier::registry::CopiesRegistry`] but fleet-visible);
    /// the node is `None` for shared tiers like the PFS.
    tier_copies: Vec<(Tier, Option<usize>)>,
    /// Publishes rejected for presenting a stale epoch — surfaced in
    /// the snapshot so storms that raced a commit are visible.
    rejected_publishes: u64,
    /// Nodes holding one committed erasure **strip** of the step. A
    /// strip is a fraction of a copy: holders here never enter
    /// `tier_copies`, and the stripe joins the fastest-surviving walk
    /// only once ≥ `erasure_k` of them are live.
    strip_holders: BTreeSet<usize>,
    /// Data-strip count k of the stripe (0 = no stripe registered).
    erasure_k: usize,
}

/// Fleet-wide (step, chunk) copy tracker. Interior-mutable: one shared
/// instance is handed to every reader of a storm and to the cascade.
#[derive(Debug, Default)]
pub struct SwarmRegistry {
    inner: Mutex<Inner>,
}

#[derive(Debug, Default)]
struct Inner {
    steps: BTreeMap<u64, StepState>,
    dead: BTreeSet<usize>,
    /// Nodes revived after a failure whose copies have not yet been
    /// re-published against a current commit epoch. Their stale
    /// pre-failure state must not re-enter holder sets through the
    /// unchecked mirror path.
    revived: BTreeSet<usize>,
}

impl SwarmRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Take the fleet lock, recovering from poisoning: a reader thread
    /// panicking mid-storm must not take the fleet-wide control plane
    /// down with it (the same pattern as
    /// [`crate::iobackend::shared::NodeRing`]). The state is a plain
    /// copies index — every mutation leaves it consistent, so the
    /// poison flag carries no information worth cascading panics for.
    fn lock(&self) -> MutexGuard<'_, Inner> {
        self.inner.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Start tracking `step`'s chunk distribution: `n_chunks` chunk
    /// slots, publishes gated on `epoch`. Re-registering resets the
    /// chunk state (a new commit of the same step id supersedes the
    /// old copies) but keeps whole-step tier copies — those are
    /// mirrored independently by the cascades and outlive any one
    /// storm.
    pub fn register_step(&self, step: u64, n_chunks: usize, epoch: &str) {
        let mut g = self.lock();
        let st = g.steps.entry(step).or_default();
        st.epoch = epoch.to_string();
        st.holders = vec![BTreeSet::new(); n_chunks];
        st.rejected_publishes = 0;
    }

    /// Node `node` claims a committed copy of `chunk`. Returns whether
    /// the publish was accepted; a stale/missing epoch, an unknown
    /// step, an out-of-range chunk, or a dead node is rejected.
    pub fn publish(&self, step: u64, node: usize, chunk: usize, epoch: &str) -> bool {
        let mut g = self.lock();
        if g.dead.contains(&node) {
            if let Some(st) = g.steps.get_mut(&step) {
                st.rejected_publishes += 1;
            }
            return false;
        }
        let Some(st) = g.steps.get_mut(&step) else {
            return false;
        };
        if st.epoch != epoch || chunk >= st.holders.len() {
            st.rejected_publishes += 1;
            return false;
        }
        st.holders[chunk].insert(node);
        // Presenting the current commit epoch proves the node has
        // re-synced past any pre-failure state: lift the post-revival
        // quarantine.
        g.revived.remove(&node);
        true
    }

    /// Declare `node` dead: its chunk and tier copies stop being
    /// served, and future publishes from it are refused until it
    /// re-registers copies after [`Self::revive_node`].
    pub fn fail_node(&self, node: usize) {
        let mut g = self.lock();
        g.dead.insert(node);
        for st in g.steps.values_mut() {
            for h in &mut st.holders {
                h.remove(&node);
            }
            st.tier_copies.retain(|(_, n)| *n != Some(node));
            st.strip_holders.remove(&node);
        }
    }

    /// Clear a node's dead flag. The node rejoined *empty* as far as
    /// the fleet is concerned: any residual holder or tier-copy
    /// entries are purged (defense in depth — `fail_node` already
    /// removed them), and the node is quarantined until it re-publishes
    /// against a step's **current** commit epoch. A revived node
    /// replaying its pre-failure disk state presents the old epoch and
    /// lands in `rejected_publishes`, never in a holder set.
    pub fn revive_node(&self, node: usize) {
        let mut g = self.lock();
        g.dead.remove(&node);
        for st in g.steps.values_mut() {
            for h in &mut st.holders {
                h.remove(&node);
            }
            st.tier_copies.retain(|(_, n)| *n != Some(node));
            st.strip_holders.remove(&node);
        }
        g.revived.insert(node);
    }

    /// Is `node` in post-revival quarantine (copies not yet
    /// re-published against a current epoch)?
    pub fn is_quarantined(&self, node: usize) -> bool {
        self.lock().revived.contains(&node)
    }

    /// Live holders of `(step, chunk)`, ascending by node.
    pub fn holders(&self, step: u64, chunk: usize) -> Vec<usize> {
        let g = self.lock();
        g.steps
            .get(&step)
            .and_then(|st| st.holders.get(chunk))
            .map(|h| h.iter().copied().collect())
            .unwrap_or_default()
    }

    /// Per-chunk live copy counts for `step` (the scheduler's
    /// rarest-first key).
    pub fn copy_counts(&self, step: u64) -> Vec<usize> {
        let g = self.lock();
        g.steps
            .get(&step)
            .map(|st| st.holders.iter().map(|h| h.len()).collect())
            .unwrap_or_default()
    }

    /// Chunks a node currently holds for `step`.
    pub fn node_chunks(&self, step: u64, node: usize) -> Vec<usize> {
        let g = self.lock();
        g.steps
            .get(&step)
            .map(|st| {
                st.holders
                    .iter()
                    .enumerate()
                    .filter(|(_, h)| h.contains(&node))
                    .map(|(i, _)| i)
                    .collect()
            })
            .unwrap_or_default()
    }

    /// Record a whole-step copy on a cascade tier (`node` is `None`
    /// for shared tiers like the PFS). Dedups; creates the step entry
    /// if no storm has registered chunks for it yet. Returns whether
    /// the copy was accepted: this is the *unchecked* mirror path used
    /// by a live cascade registering its own fresh commit, so dead
    /// nodes and nodes in post-revival quarantine are refused (counted
    /// in `rejected_publishes`) — a revived node must go through
    /// [`Self::publish_tier_copy`] with the step's current epoch first.
    pub fn record_tier_copy(&self, step: u64, tier: Tier, node: Option<usize>) -> bool {
        let mut g = self.lock();
        if let Some(n) = node {
            if g.dead.contains(&n) || g.revived.contains(&n) {
                g.steps.entry(step).or_default().rejected_publishes += 1;
                return false;
            }
        }
        let st = g.steps.entry(step).or_default();
        if !st.tier_copies.contains(&(tier, node)) {
            st.tier_copies.push((tier, node));
        }
        true
    }

    /// Epoch-checked tier-copy publication: the re-registration path
    /// for a revived node advertising copies it held before failing.
    /// Accepted only if `epoch` matches the step's current commit
    /// epoch; a stale epoch (the node's pre-failure on-disk marker)
    /// lands in `rejected_publishes` and never in the served set. A
    /// successful publish lifts the node's post-revival quarantine.
    pub fn publish_tier_copy(
        &self,
        step: u64,
        tier: Tier,
        node: Option<usize>,
        epoch: &str,
    ) -> bool {
        let mut g = self.lock();
        if let Some(n) = node {
            if g.dead.contains(&n) {
                if let Some(st) = g.steps.get_mut(&step) {
                    st.rejected_publishes += 1;
                }
                return false;
            }
        }
        let Some(st) = g.steps.get_mut(&step) else {
            return false;
        };
        if st.epoch != epoch {
            st.rejected_publishes += 1;
            return false;
        }
        if !st.tier_copies.contains(&(tier, node)) {
            st.tier_copies.push((tier, node));
        }
        if let Some(n) = node {
            g.revived.remove(&n);
        }
        true
    }

    /// Drop a whole-step tier copy (eviction).
    pub fn drop_tier_copy(&self, step: u64, tier: Tier) {
        let mut g = self.lock();
        if let Some(st) = g.steps.get_mut(&step) {
            st.tier_copies.retain(|(t, _)| *t != tier);
        }
    }

    /// Record a committed erasure **strip** of `step` at `holder`
    /// (`k` = the stripe's data-strip count). Strips are fractions of
    /// a copy: a holder here is never served as a whole-step copy, and
    /// the stripe enters [`Self::fastest_surviving`] only once ≥ k
    /// holders are live. Dead and quarantined holders are refused like
    /// the tier-copy mirror path.
    pub fn record_strip_copy(&self, step: u64, holder: usize, k: usize) -> bool {
        let mut g = self.lock();
        if g.dead.contains(&holder) || g.revived.contains(&holder) {
            g.steps.entry(step).or_default().rejected_publishes += 1;
            return false;
        }
        let st = g.steps.entry(step).or_default();
        st.strip_holders.insert(holder);
        st.erasure_k = k.max(1);
        true
    }

    /// Drop a strip record (holder eviction or strip loss).
    pub fn drop_strip_copy(&self, step: u64, holder: usize) {
        let mut g = self.lock();
        if let Some(st) = g.steps.get_mut(&step) {
            st.strip_holders.remove(&holder);
        }
    }

    /// Live strip holders of `step`, ascending by node.
    pub fn strip_holders(&self, step: u64) -> Vec<usize> {
        let g = self.lock();
        g.steps
            .get(&step)
            .map(|st| st.strip_holders.iter().copied().collect())
            .unwrap_or_default()
    }

    /// The fastest surviving whole-step copy of `step`, by restore
    /// preference: device, then a live buddy replica, then a
    /// reconstructible erasure stripe, then storage tiers
    /// fastest-first. The stripe qualifies **only** when ≥ k strip
    /// holders are live — a node holding one strip is never hinted as
    /// a restorable whole-step copy, and a `Tier::Erasure` entry
    /// mirrored into `tier_copies` is filtered out the moment the
    /// stripe drops below k.
    pub fn fastest_surviving(&self, step: u64) -> Option<Tier> {
        let g = self.lock();
        let st = g.steps.get(&step)?;
        let stripe_ok = st.erasure_k > 0 && st.strip_holders.len() >= st.erasure_k;
        st.tier_copies
            .iter()
            .map(|(t, _)| *t)
            .filter(|t| *t != Tier::Erasure || stripe_ok)
            .chain(if stripe_ok { Some(Tier::Erasure) } else { None })
            .min_by_key(|t| match t {
                Tier::Device => 0usize,
                Tier::Replica(_) => 1,
                Tier::Erasure => 2,
                Tier::Storage(i) => 3 + i,
            })
    }

    /// Fleet snapshot as JSON (emitted next to the fig25 artifacts and
    /// schema-checked by CI): per step the epoch, chunk copy counts,
    /// holder sets, tier copies, and rejected-publish tally, plus the
    /// dead-node set.
    pub fn snapshot_json(&self) -> Json {
        let g = self.lock();
        let mut steps = Vec::new();
        for (step, st) in &g.steps {
            let mut holders = Vec::new();
            for h in &st.holders {
                holders.push(Json::Arr(
                    h.iter().map(|n| Json::from(*n)).collect(),
                ));
            }
            let mut tiers = Vec::new();
            for (t, n) in &st.tier_copies {
                let mut o = Json::obj();
                o.set("tier", t.to_string());
                match n {
                    Some(n) => o.set("node", *n),
                    None => o.set("node", "shared"),
                };
                tiers.push(o);
            }
            let mut s = Json::obj();
            s.set("step", *step)
                .set("epoch", st.epoch.as_str())
                .set("n_chunks", st.holders.len())
                .set(
                    "copy_counts",
                    Json::Arr(st.holders.iter().map(|h| Json::from(h.len())).collect()),
                )
                .set("holders", Json::Arr(holders))
                .set("tier_copies", Json::Arr(tiers))
                .set("rejected_publishes", st.rejected_publishes)
                .set(
                    "strip_holders",
                    Json::Arr(st.strip_holders.iter().map(|n| Json::from(*n)).collect()),
                )
                .set("erasure_k", st.erasure_k);
            steps.push(s);
        }
        let mut out = Json::obj();
        out.set("steps", Json::Arr(steps)).set(
            "dead_nodes",
            Json::Arr(g.dead.iter().map(|n| Json::from(*n)).collect()),
        );
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn publish_is_epoch_gated() {
        let r = SwarmRegistry::new();
        r.register_step(7, 3, "e1");
        assert!(r.publish(7, 0, 1, "e1"));
        assert!(!r.publish(7, 1, 1, "stale"));
        assert!(!r.publish(7, 1, 9, "e1"));
        assert!(!r.publish(8, 1, 0, "e1"));
        assert_eq!(r.holders(7, 1), vec![0]);
        let snap = r.snapshot_json().to_pretty();
        assert!(snap.contains("\"rejected_publishes\": 2"));
    }

    #[test]
    fn fail_node_removes_copies_and_blocks_publishes() {
        let r = SwarmRegistry::new();
        r.register_step(1, 2, "e");
        assert!(r.publish(1, 3, 0, "e"));
        r.record_tier_copy(1, Tier::Replica(3), Some(3));
        r.record_tier_copy(1, Tier::Storage(1), None);
        r.fail_node(3);
        assert!(r.holders(1, 0).is_empty());
        assert!(!r.publish(1, 3, 0, "e"));
        assert_eq!(r.fastest_surviving(1), Some(Tier::Storage(1)));
        r.revive_node(3);
        assert!(r.publish(1, 3, 0, "e"));
    }

    #[test]
    fn fastest_surviving_prefers_device_then_replica() {
        let r = SwarmRegistry::new();
        r.register_step(5, 1, "e");
        assert_eq!(r.fastest_surviving(5), None);
        r.record_tier_copy(5, Tier::Storage(1), None);
        r.record_tier_copy(5, Tier::Storage(0), Some(2));
        assert_eq!(r.fastest_surviving(5), Some(Tier::Storage(0)));
        r.record_tier_copy(5, Tier::Replica(4), Some(4));
        assert_eq!(r.fastest_surviving(5), Some(Tier::Replica(4)));
        r.record_tier_copy(5, Tier::Device, Some(0));
        assert_eq!(r.fastest_surviving(5), Some(Tier::Device));
        r.drop_tier_copy(5, Tier::Device);
        assert_eq!(r.fastest_surviving(5), Some(Tier::Replica(4)));
    }

    #[test]
    fn poisoned_lock_does_not_take_down_subsequent_publishes() {
        // A reader thread panicking while holding the fleet lock used
        // to poison it and cascade panics into every surviving node's
        // restore walk. The lock now recovers from poisoning.
        use std::sync::Arc;
        let r = Arc::new(SwarmRegistry::new());
        r.register_step(1, 2, "e");
        let r2 = Arc::clone(&r);
        let joined = std::thread::spawn(move || {
            let _g = r2.lock();
            panic!("reader dies mid-storm holding the fleet lock");
        })
        .join();
        assert!(joined.is_err(), "the thread must actually have panicked");
        // Control plane still serves: publishes, queries, snapshots.
        assert!(r.publish(1, 0, 0, "e"));
        assert_eq!(r.holders(1, 0), vec![0]);
        assert!(r.record_tier_copy(1, Tier::Storage(0), Some(0)));
        assert_eq!(r.fastest_surviving(1), Some(Tier::Storage(0)));
        assert!(r.snapshot_json().to_pretty().contains("\"step\": 1"));
    }

    #[test]
    fn revived_node_stale_copies_are_epoch_gated() {
        // fail → commit-new-epoch → revive: the revived node replaying
        // its pre-failure disk state must land in rejected_publishes,
        // not in holder sets or the fastest-surviving walk.
        let r = SwarmRegistry::new();
        r.register_step(4, 2, "e1");
        assert!(r.publish(4, 2, 0, "e1"));
        assert!(r.record_tier_copy(4, Tier::Storage(0), Some(2)));
        r.fail_node(2);
        // A new commit of the step supersedes the old epoch while the
        // node is down.
        r.register_step(4, 2, "e2");
        r.revive_node(2);
        assert!(r.is_quarantined(2));
        // Stale re-publication with the pre-failure epoch: rejected and
        // counted, holders stay empty, nothing served.
        assert!(!r.publish(4, 2, 0, "e1"));
        assert!(!r.publish_tier_copy(4, Tier::Storage(0), Some(2), "e1"));
        assert!(r.holders(4, 0).is_empty());
        assert_eq!(r.fastest_surviving(4), None);
        // The unchecked cascade-mirror path is also refused while
        // quarantined.
        assert!(!r.record_tier_copy(4, Tier::Storage(0), Some(2)));
        assert_eq!(r.fastest_surviving(4), None);
        let snap = r.snapshot_json().to_pretty();
        assert!(snap.contains("\"rejected_publishes\": 3"), "{snap}");
        // Re-publishing against the current epoch restores service and
        // lifts the quarantine.
        assert!(r.publish_tier_copy(4, Tier::Storage(0), Some(2), "e2"));
        assert!(!r.is_quarantined(2));
        assert_eq!(r.fastest_surviving(4), Some(Tier::Storage(0)));
        assert!(r.record_tier_copy(4, Tier::Device, Some(2)));
        assert_eq!(r.fastest_surviving(4), Some(Tier::Device));
    }

    #[test]
    fn strip_holders_never_hinted_as_whole_copies() {
        let r = SwarmRegistry::new();
        r.register_step(9, 1, "e");
        // RS(k=4): five strip holders trickle in. Below k the stripe
        // must not surface at all — a strip holder is not a copy.
        for h in [1, 2, 3] {
            assert!(r.record_strip_copy(9, h, 4));
        }
        assert_eq!(r.fastest_surviving(9), None);
        assert_eq!(r.strip_holders(9), vec![1, 2, 3]);
        for h in [4, 5] {
            assert!(r.record_strip_copy(9, h, 4));
        }
        // ≥ k live: the stripe is one surviving copy, ranked between
        // replicas and storage.
        assert_eq!(r.fastest_surviving(9), Some(Tier::Erasure));
        r.record_tier_copy(9, Tier::Storage(1), None);
        assert_eq!(r.fastest_surviving(9), Some(Tier::Erasure));
        r.record_tier_copy(9, Tier::Replica(7), Some(7));
        assert_eq!(r.fastest_surviving(9), Some(Tier::Replica(7)));
        // Holder losses: stripe drops out exactly below k, even if a
        // Tier::Erasure entry was mirrored into tier_copies directly.
        r.record_tier_copy(9, Tier::Erasure, None);
        r.drop_tier_copy(9, Tier::Replica(7));
        r.fail_node(5);
        assert_eq!(r.fastest_surviving(9), Some(Tier::Erasure));
        r.drop_strip_copy(9, 4);
        assert_eq!(r.strip_holders(9), vec![1, 2, 3]);
        assert_eq!(r.fastest_surviving(9), Some(Tier::Storage(1)));
        // Dead holders are refused on the record path.
        assert!(!r.record_strip_copy(9, 5, 4));
        let snap = r.snapshot_json().to_pretty();
        assert!(snap.contains("\"erasure_k\": 4"), "{snap}");
    }

    #[test]
    fn copy_counts_track_rarest_first_key() {
        let r = SwarmRegistry::new();
        r.register_step(2, 3, "e");
        r.publish(2, 0, 0, "e");
        r.publish(2, 1, 0, "e");
        r.publish(2, 0, 2, "e");
        assert_eq!(r.copy_counts(2), vec![2, 0, 1]);
        assert_eq!(r.node_chunks(2, 0), vec![0, 2]);
    }
}
