//! Fleet-wide copies control plane.
//!
//! [`SwarmRegistry`] is the distributed big sibling of
//! [`crate::tier::registry::CopiesRegistry`]: where that one tracks
//! which *tiers* hold a step on a single cascade, this one tracks
//! every (step, chunk) copy across every node in the fleet, plus
//! whole-step tier copies, so both the swarm scheduler and
//! `TierCascade::restore_via` can ask for the fastest surviving
//! source after failures.
//!
//! Publishes are epoch-gated: a node registers a chunk copy only by
//! presenting the step's commit epoch (the value of the PFS
//! `.ckpt_epoch` marker at commit time). A peer store left over from
//! an earlier run — or one whose storm died before the commit rename —
//! carries a stale or missing epoch and its publishes are rejected, so
//! the registry can never direct a reader at uncommitted bytes.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::Mutex;

use crate::tier::Tier;
use crate::util::json::Json;

/// Per-step distribution state.
#[derive(Debug, Default)]
struct StepState {
    /// Commit epoch the step was registered with; publishes must match.
    epoch: String,
    /// One holder set per chunk index.
    holders: Vec<BTreeSet<usize>>,
    /// Whole-step copies by cascade tier (mirrors
    /// [`crate::tier::registry::CopiesRegistry`] but fleet-visible);
    /// the node is `None` for shared tiers like the PFS.
    tier_copies: Vec<(Tier, Option<usize>)>,
    /// Publishes rejected for presenting a stale epoch — surfaced in
    /// the snapshot so storms that raced a commit are visible.
    rejected_publishes: u64,
}

/// Fleet-wide (step, chunk) copy tracker. Interior-mutable: one shared
/// instance is handed to every reader of a storm and to the cascade.
#[derive(Debug, Default)]
pub struct SwarmRegistry {
    inner: Mutex<Inner>,
}

#[derive(Debug, Default)]
struct Inner {
    steps: BTreeMap<u64, StepState>,
    dead: BTreeSet<usize>,
}

impl SwarmRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Start tracking `step`'s chunk distribution: `n_chunks` chunk
    /// slots, publishes gated on `epoch`. Re-registering resets the
    /// chunk state (a new commit of the same step id supersedes the
    /// old copies) but keeps whole-step tier copies — those are
    /// mirrored independently by the cascades and outlive any one
    /// storm.
    pub fn register_step(&self, step: u64, n_chunks: usize, epoch: &str) {
        let mut g = self.inner.lock().unwrap();
        let st = g.steps.entry(step).or_default();
        st.epoch = epoch.to_string();
        st.holders = vec![BTreeSet::new(); n_chunks];
        st.rejected_publishes = 0;
    }

    /// Node `node` claims a committed copy of `chunk`. Returns whether
    /// the publish was accepted; a stale/missing epoch, an unknown
    /// step, an out-of-range chunk, or a dead node is rejected.
    pub fn publish(&self, step: u64, node: usize, chunk: usize, epoch: &str) -> bool {
        let mut g = self.inner.lock().unwrap();
        if g.dead.contains(&node) {
            return false;
        }
        let Some(st) = g.steps.get_mut(&step) else {
            return false;
        };
        if st.epoch != epoch || chunk >= st.holders.len() {
            st.rejected_publishes += 1;
            return false;
        }
        st.holders[chunk].insert(node);
        true
    }

    /// Declare `node` dead: its chunk and tier copies stop being
    /// served, and future publishes from it are refused until it
    /// re-registers copies after [`Self::revive_node`].
    pub fn fail_node(&self, node: usize) {
        let mut g = self.inner.lock().unwrap();
        g.dead.insert(node);
        for st in g.steps.values_mut() {
            for h in &mut st.holders {
                h.remove(&node);
            }
            st.tier_copies.retain(|(_, n)| *n != Some(node));
        }
    }

    /// Clear a node's dead flag (it rejoined empty; copies must be
    /// re-published).
    pub fn revive_node(&self, node: usize) {
        self.inner.lock().unwrap().dead.remove(&node);
    }

    /// Live holders of `(step, chunk)`, ascending by node.
    pub fn holders(&self, step: u64, chunk: usize) -> Vec<usize> {
        let g = self.inner.lock().unwrap();
        g.steps
            .get(&step)
            .and_then(|st| st.holders.get(chunk))
            .map(|h| h.iter().copied().collect())
            .unwrap_or_default()
    }

    /// Per-chunk live copy counts for `step` (the scheduler's
    /// rarest-first key).
    pub fn copy_counts(&self, step: u64) -> Vec<usize> {
        let g = self.inner.lock().unwrap();
        g.steps
            .get(&step)
            .map(|st| st.holders.iter().map(|h| h.len()).collect())
            .unwrap_or_default()
    }

    /// Chunks a node currently holds for `step`.
    pub fn node_chunks(&self, step: u64, node: usize) -> Vec<usize> {
        let g = self.inner.lock().unwrap();
        g.steps
            .get(&step)
            .map(|st| {
                st.holders
                    .iter()
                    .enumerate()
                    .filter(|(_, h)| h.contains(&node))
                    .map(|(i, _)| i)
                    .collect()
            })
            .unwrap_or_default()
    }

    /// Record a whole-step copy on a cascade tier (`node` is `None`
    /// for shared tiers like the PFS). Dedups; creates the step entry
    /// if no storm has registered chunks for it yet.
    pub fn record_tier_copy(&self, step: u64, tier: Tier, node: Option<usize>) {
        let mut g = self.inner.lock().unwrap();
        if let Some(dead) = node {
            if g.dead.contains(&dead) {
                return;
            }
        }
        let st = g.steps.entry(step).or_default();
        if !st.tier_copies.contains(&(tier, node)) {
            st.tier_copies.push((tier, node));
        }
    }

    /// Drop a whole-step tier copy (eviction).
    pub fn drop_tier_copy(&self, step: u64, tier: Tier) {
        let mut g = self.inner.lock().unwrap();
        if let Some(st) = g.steps.get_mut(&step) {
            st.tier_copies.retain(|(t, _)| *t != tier);
        }
    }

    /// The fastest surviving whole-step copy of `step`, by restore
    /// preference: device, then a live buddy replica, then storage
    /// tiers fastest-first.
    pub fn fastest_surviving(&self, step: u64) -> Option<Tier> {
        let g = self.inner.lock().unwrap();
        let st = g.steps.get(&step)?;
        st.tier_copies
            .iter()
            .map(|(t, _)| *t)
            .min_by_key(|t| match t {
                Tier::Device => 0usize,
                Tier::Replica(_) => 1,
                Tier::Storage(i) => 2 + i,
            })
    }

    /// Fleet snapshot as JSON (emitted next to the fig25 artifacts and
    /// schema-checked by CI): per step the epoch, chunk copy counts,
    /// holder sets, tier copies, and rejected-publish tally, plus the
    /// dead-node set.
    pub fn snapshot_json(&self) -> Json {
        let g = self.inner.lock().unwrap();
        let mut steps = Vec::new();
        for (step, st) in &g.steps {
            let mut holders = Vec::new();
            for h in &st.holders {
                holders.push(Json::Arr(
                    h.iter().map(|n| Json::from(*n)).collect(),
                ));
            }
            let mut tiers = Vec::new();
            for (t, n) in &st.tier_copies {
                let mut o = Json::obj();
                o.set("tier", t.to_string());
                match n {
                    Some(n) => o.set("node", *n),
                    None => o.set("node", "shared"),
                };
                tiers.push(o);
            }
            let mut s = Json::obj();
            s.set("step", *step)
                .set("epoch", st.epoch.as_str())
                .set("n_chunks", st.holders.len())
                .set(
                    "copy_counts",
                    Json::Arr(st.holders.iter().map(|h| Json::from(h.len())).collect()),
                )
                .set("holders", Json::Arr(holders))
                .set("tier_copies", Json::Arr(tiers))
                .set("rejected_publishes", st.rejected_publishes);
            steps.push(s);
        }
        let mut out = Json::obj();
        out.set("steps", Json::Arr(steps)).set(
            "dead_nodes",
            Json::Arr(g.dead.iter().map(|n| Json::from(*n)).collect()),
        );
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn publish_is_epoch_gated() {
        let r = SwarmRegistry::new();
        r.register_step(7, 3, "e1");
        assert!(r.publish(7, 0, 1, "e1"));
        assert!(!r.publish(7, 1, 1, "stale"));
        assert!(!r.publish(7, 1, 9, "e1"));
        assert!(!r.publish(8, 1, 0, "e1"));
        assert_eq!(r.holders(7, 1), vec![0]);
        let snap = r.snapshot_json().to_pretty();
        assert!(snap.contains("\"rejected_publishes\": 2"));
    }

    #[test]
    fn fail_node_removes_copies_and_blocks_publishes() {
        let r = SwarmRegistry::new();
        r.register_step(1, 2, "e");
        assert!(r.publish(1, 3, 0, "e"));
        r.record_tier_copy(1, Tier::Replica(3), Some(3));
        r.record_tier_copy(1, Tier::Storage(1), None);
        r.fail_node(3);
        assert!(r.holders(1, 0).is_empty());
        assert!(!r.publish(1, 3, 0, "e"));
        assert_eq!(r.fastest_surviving(1), Some(Tier::Storage(1)));
        r.revive_node(3);
        assert!(r.publish(1, 3, 0, "e"));
    }

    #[test]
    fn fastest_surviving_prefers_device_then_replica() {
        let r = SwarmRegistry::new();
        r.register_step(5, 1, "e");
        assert_eq!(r.fastest_surviving(5), None);
        r.record_tier_copy(5, Tier::Storage(1), None);
        r.record_tier_copy(5, Tier::Storage(0), Some(2));
        assert_eq!(r.fastest_surviving(5), Some(Tier::Storage(0)));
        r.record_tier_copy(5, Tier::Replica(4), Some(4));
        assert_eq!(r.fastest_surviving(5), Some(Tier::Replica(4)));
        r.record_tier_copy(5, Tier::Device, Some(0));
        assert_eq!(r.fastest_surviving(5), Some(Tier::Device));
        r.drop_tier_copy(5, Tier::Device);
        assert_eq!(r.fastest_surviving(5), Some(Tier::Replica(4)));
    }

    #[test]
    fn copy_counts_track_rarest_first_key() {
        let r = SwarmRegistry::new();
        r.register_step(2, 3, "e");
        r.publish(2, 0, 0, "e");
        r.publish(2, 1, 0, "e");
        r.publish(2, 0, 2, "e");
        assert_eq!(r.copy_counts(2), vec![2, 0, 1]);
        assert_eq!(r.node_chunks(2, 0), vec![0, 2]);
    }
}
