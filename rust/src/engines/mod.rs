//! The checkpoint/restore engines under study.
//!
//! Every engine is a *plan compiler*: given the per-rank shard sets of a
//! checkpoint ([`RankShard`]), it emits [`RankPlan`]s reproducing that
//! engine's documented I/O pattern — file layout, submission granularity,
//! staging discipline, allocation policy. Plans run unchanged on the real
//! executor (io_uring/POSIX on local files) and on the Polaris simulator.
//!
//! | Engine | Layout | Submission | Restore allocation |
//! |---|---|---|---|
//! | [`UringBaseline`] | aggregated (configurable) | deep-queue batched liburing, O_DIRECT | preallocated pooled buffers |
//! | [`DataStatesLlm`] | file-per-shard (N·M files) | liburing, submit-per-object | dynamic per-read alloc |
//! | [`TorchSnapshot`] | 512 MB chunk files in nested dirs | libaio, shallow queue | dynamic, serial reads |
//! | [`TorchSave`] | file-per-object, monolithic | synchronous buffered POSIX | whole-object alloc |

pub mod baseline;
pub mod datastates;
pub mod torchsave;
pub mod torchsnapshot;

use crate::plan::RankPlan;
use crate::simpfs::exec::SubmitMode;
use crate::workload::layout::RankShard;

pub use baseline::UringBaseline;
pub use datastates::DataStatesLlm;
pub use torchsave::TorchSave;
pub use torchsnapshot::TorchSnapshot;

/// Shared engine-invocation context.
#[derive(Debug, Clone)]
pub struct EngineCtx {
    /// O_DIRECT alignment for offsets/lengths.
    pub align: u64,
    /// Ranks per node (node id = rank / ranks_per_node).
    pub ranks_per_node: usize,
    /// Include GPU↔host staging in the plans (end-to-end Figure 3 mode);
    /// the synthetic benchmarks flush host-resident buffers and set this
    /// false.
    pub include_device_transfers: bool,
    /// Model the serialized prefix-sum offset exchange of the shared
    /// file layout (the paper's §3.6 LLM benchmark with irregular
    /// sizes). Synthetic power-of-two workloads precompute offsets.
    pub serialize_offsets: bool,
    /// LLM-realistic mode: tensors arrive with irregular, unaligned
    /// sizes, so O_DIRECT engines must bounce-copy them into aligned
    /// staging buffers (the paper's §3.6 "explicit offset alignment for
    /// each buffer"). Synthetic power-of-two workloads skip this.
    pub bounce_unaligned: bool,
    /// Transfer chunk size (the paper: 64 MB regions).
    pub chunk_bytes: u64,
    /// Coalesce runs of adjacent items smaller than this into single
    /// submissions (0 = off). The paper's §5 future-work item
    /// ("coalesce small objects into larger I/O operations");
    /// `ablation_coalescing` measures it.
    pub coalesce_bytes: u64,
    /// Submission queue depth for deep-queue engines.
    pub queue_depth: u32,
    /// Opt-in io_uring accelerations (fixed files, SQPOLL, linked
    /// fsync, shared per-node ring) requested for uring-mode engines.
    /// The real executor degrades per-feature when the kernel refuses;
    /// the simulator mirrors each knob as a submit-path cost delta.
    pub uring: crate::uring::UringFeatures,
}

impl Default for EngineCtx {
    fn default() -> Self {
        Self {
            align: crate::util::align::DIRECT_IO_ALIGN,
            ranks_per_node: 4,
            include_device_transfers: false,
            serialize_offsets: false,
            bounce_unaligned: false,
            chunk_bytes: 64 * crate::util::bytes::MIB,
            coalesce_bytes: 0,
            queue_depth: 32,
            uring: crate::uring::UringFeatures::none(),
        }
    }
}

impl EngineCtx {
    pub fn node_of(&self, rank: usize) -> usize {
        rank / self.ranks_per_node
    }
}

/// A checkpoint/restore engine.
pub trait CkptEngine: Send + Sync {
    fn name(&self) -> &'static str;

    /// Which userspace submission interface the engine uses (drives both
    /// simulator costs and, where applicable, the real backend choice).
    fn submit_mode(&self) -> SubmitMode;

    /// Compile the checkpoint (write) plans, one per rank.
    fn plan_checkpoint(&self, shards: &[RankShard], ctx: &EngineCtx) -> Vec<RankPlan>;

    /// Compile the restore (read) plans, one per rank. Paths must match
    /// what `plan_checkpoint` wrote.
    fn plan_restore(&self, shards: &[RankShard], ctx: &EngineCtx) -> Vec<RankPlan>;
}

/// Join an optional tier prefix onto an engine-generated path — the
/// cascade-targeting knob. A prefix of [`crate::tier::LOCAL_TIER_PREFIX`]
/// routes the plan's files to the burst-buffer tier on both substrates
/// (a directory on the real executor, the local-SSD servers in the
/// simulator).
pub(crate) fn tier_join(prefix: &Option<String>, path: &str) -> String {
    match prefix {
        Some(p) => crate::tier::tier_path(p, path),
        None => path.to_string(),
    }
}

/// Push writes for the byte range `[start, start+len)` of `file`,
/// chunked at `chunk` bytes, with staging offsets advancing in lockstep.
pub(crate) fn push_chunked(
    plan: &mut RankPlan,
    write: bool,
    file: usize,
    mut offset: u64,
    mut staging: u64,
    mut len: u64,
    chunk: u64,
) {
    use crate::plan::{BufSlice, PlanOp};
    while len > 0 {
        let n = len.min(chunk);
        let slice = BufSlice::new(staging, n);
        plan.push(if write {
            PlanOp::Write {
                file,
                offset,
                src: slice,
            }
        } else {
            PlanOp::Read {
                file,
                offset,
                dst: slice,
            }
        });
        offset += n;
        staging += n;
        len -= n;
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use crate::workload::layout::RankShard;
    use crate::workload::synthetic::Synthetic;
    use crate::workload::{CheckpointLayout, ModelSpec, Parallelism};

    /// A small realistic multi-rank shard set (tiny model, tp=2).
    pub fn tiny_shards() -> Vec<RankShard> {
        CheckpointLayout::derive(&ModelSpec::tiny_100m(), Parallelism::new(2, 1, 1)).shards
    }

    /// A small synthetic shard set.
    pub fn synthetic_shards() -> Vec<RankShard> {
        Synthetic::new(2, 16 * crate::util::bytes::MIB).shards()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::{PlanOp, RankPlan};

    #[test]
    fn chunking_covers_range_exactly() {
        let mut p = RankPlan::new(0, 0);
        p.add_file(crate::plan::FileSpec {
            path: "x".into(),
            direct: true,
            size_hint: 0,
            creates: true,
        });
        push_chunked(&mut p, true, 0, 100, 0, 250, 64);
        let writes: Vec<(u64, u64)> = p
            .ops
            .iter()
            .map(|op| match op {
                PlanOp::Write { offset, src, .. } => (*offset, src.len),
                _ => panic!(),
            })
            .collect();
        assert_eq!(writes, vec![(100, 64), (164, 64), (228, 64), (292, 58)]);
        assert_eq!(p.write_bytes(), 250);
    }
}
