//! The liburing aggregated baseline — the paper's "ideal approach".
//!
//! This is the engine the paper's microbenchmark models and its
//! Conclusions recommend: tensors, lean state and metadata coalesced
//! into large aligned regions of few files (configurable aggregation
//! strategy), flushed with deep-queue batched io_uring submissions under
//! O_DIRECT, and restored into *preallocated, reused* aligned buffers —
//! no per-read allocation.

use crate::ckpt::aggregation::{plan_offsets, shared_file_bases, Aggregation, ItemKind};
use crate::plan::{FileSpec, PlanOp, RankPlan};
use crate::simpfs::exec::SubmitMode;
use crate::util::prng::Xoshiro256;
use crate::workload::layout::RankShard;

use super::{push_chunked, CkptEngine, EngineCtx};

/// Configuration of the baseline engine.
#[derive(Debug, Clone)]
pub struct UringBaseline {
    pub aggregation: Aggregation,
    /// O_DIRECT on (the paper keeps it on for reads and writes, §3.4).
    pub direct: bool,
    /// Submission interface (Posix turns this engine into the POSIX
    /// baseline of Figures 9–10).
    pub mode: SubmitMode,
    /// Cascade-targeting knob: place every file under this tier prefix
    /// (e.g. [`crate::tier::LOCAL_TIER_PREFIX`] stages the checkpoint
    /// into the burst-buffer tier instead of straight to the PFS).
    pub tier_prefix: Option<String>,
    /// Source plans from the device tier: checkpoints start with the
    /// PCIe D2H drain of the GPU-resident state and restores end with
    /// the H2D placement, regardless of
    /// `EngineCtx::include_device_transfers` — the cascade's tier-0
    /// lifecycle (device → host → storage).
    pub from_device: bool,
    /// Delta-checkpoint modeling knob: the fraction of tensor items
    /// whose content hash matched the parent step, so the write path
    /// never stages or submits them (see [`crate::ckpt::delta`]). The
    /// skip is a deterministic per-rank draw; restores still read full
    /// state. 0.0 = every save is a full snapshot.
    pub stable_fraction: f64,
}

impl Default for UringBaseline {
    fn default() -> Self {
        Self {
            aggregation: Aggregation::SharedFile,
            direct: true,
            mode: SubmitMode::Uring,
            tier_prefix: None,
            from_device: false,
            stable_fraction: 0.0,
        }
    }
}

impl UringBaseline {
    pub fn new(aggregation: Aggregation) -> Self {
        Self {
            aggregation,
            ..Default::default()
        }
    }

    pub fn buffered(mut self) -> Self {
        self.direct = false;
        self
    }

    pub fn posix(mut self) -> Self {
        self.mode = SubmitMode::Posix;
        self
    }

    /// Target the plans at a cascade tier (see `tier_prefix`).
    pub fn on_tier(mut self, prefix: impl Into<String>) -> Self {
        self.tier_prefix = Some(prefix.into());
        self
    }

    /// Source plans from the device tier (see `from_device`).
    pub fn from_device(mut self) -> Self {
        self.from_device = true;
        self
    }

    /// Model delta checkpointing (see `stable_fraction`).
    pub fn with_stable_fraction(mut self, f: f64) -> Self {
        self.stable_fraction = f.clamp(0.0, 1.0);
        self
    }

    fn plan_rank(
        &self,
        shard: &RankShard,
        base: u64,
        ctx: &EngineCtx,
        write: bool,
    ) -> RankPlan {
        let offsets = plan_offsets(self.aggregation, shard, base, ctx.align);
        let mut plan = RankPlan::new(shard.rank, ctx.node_of(shard.rank));

        // Register files.
        for f in &offsets.files {
            plan.add_file(FileSpec {
                path: super::tier_join(&self.tier_prefix, &f.path),
                direct: self.direct,
                size_hint: if self.aggregation == Aggregation::SharedFile {
                    // Shared file: creator sizes the whole extent; the
                    // final base from the prefix sum isn't known here, so
                    // size generously from this rank's knowledge.
                    0
                } else {
                    f.extent
                },
                creates: if write { f.creates } else { false },
            });
        }

        plan.push(PlanOp::QueueDepth {
            qd: ctx.queue_depth,
        });

        let device = self.from_device || ctx.include_device_transfers;
        if write {
            if device {
                // Stage all GPU-resident tensors to pinned host buffers;
                // the lean state is serialized once.
                plan.push(PlanOp::D2H {
                    bytes: shard.gpu_bytes(),
                });
                if shard.lean_bytes() > 0 {
                    plan.push(PlanOp::Serialize {
                        bytes: shard.lean_bytes(),
                    });
                }
            }
            // Shared file: rank 0 creates, everyone else opens after a
            // barrier; irregular layouts additionally serialize the
            // offset prefix-sum through a token chain (§3.6).
            match self.aggregation {
                Aggregation::SharedFile => {
                    if shard.rank == 0 {
                        plan.push(PlanOp::Create { file: 0 });
                    }
                    plan.push(PlanOp::Barrier { id: 9000 });
                    if shard.rank != 0 {
                        plan.push(PlanOp::Open { file: 0 });
                    }
                    if ctx.serialize_offsets {
                        plan.push(PlanOp::TokenRecv { chain: 9001 });
                        plan.push(PlanOp::TokenSend { chain: 9001 });
                    }
                }
                _ => {
                    for f in 0..offsets.files.len() {
                        plan.push(PlanOp::Create { file: f });
                    }
                }
            }
        } else {
            for f in 0..offsets.files.len() {
                plan.push(PlanOp::Open { file: f });
            }
            // Restore starts by reading the rank's metadata header —
            // the first (small) item of the plan.
        }

        // Delta modeling: stable tensor items (hash matched the parent)
        // never enter the write plan at all — not staged, not
        // submitted, not fsync-extended. A deterministic per-rank draw
        // keeps the grid reproducible across runs. Restores always
        // read full state: the chain walk serves inherited chunks from
        // ancestor packs at the same read cost.
        let items: Vec<crate::ckpt::aggregation::PlacedItem> =
            if write && self.stable_fraction > 0.0 {
                let mut rng = Xoshiro256::seeded(0xDE17A ^ ((shard.rank as u64) << 32));
                offsets
                    .items
                    .iter()
                    .filter(|it| {
                        !(matches!(it.kind, ItemKind::Tensor { .. })
                            && rng.next_f64() < self.stable_fraction)
                    })
                    .cloned()
                    .collect()
            } else {
                offsets.items.clone()
            };

        // Data movement, chunked at the staging granularity. No Alloc
        // ops anywhere: buffers are preallocated and reused (the pool).
        //
        // Coalescing (ctx.coalesce_bytes > 0): runs of adjacent small
        // items in the same file merge into one submission — fewer,
        // larger I/O ops, less per-request overhead (the paper's §5
        // recommendation). Items are contiguous in both file offset and
        // staging space by construction of `plan_offsets`, so merging is
        // a pure range union. Disabled in bounce/meta-drain paths where
        // per-item ordering matters on restore.
        let coalesced = if ctx.coalesce_bytes > 0 && !ctx.bounce_unaligned {
            coalesce_items(&items, ctx.coalesce_bytes, write)
        } else {
            items
                .iter()
                .map(|it| CoalescedRun {
                    file: it.file,
                    offset: it.offset,
                    staging_off: it.staging_off,
                    len: it.padded_len,
                    // The logical payload is unaligned → O_DIRECT needs
                    // a bounce copy of the payload bytes.
                    bounce_bytes: if it.len % ctx.align != 0 { it.len } else { 0 },
                    is_meta: matches!(it.kind, ItemKind::Meta { .. }),
                })
                .collect()
        };
        for item in &coalesced {
            // Irregular (unaligned) buffers bounce through a bounded set
            // of aligned staging buffers for O_DIRECT: pin+copy before
            // the writes, and (buffer reuse) drain before the next item
            // — the serialization that halves LLM-realistic throughput
            // relative to the synthetic benchmark (§3.6). (Runs are
            // aligned when coalescing is active, so `len` here is the
            // padded run length.)
            let bounced = ctx.bounce_unaligned && self.direct && item.bounce_bytes > 0;
            if bounced && write {
                plan.push(PlanOp::BounceCopy {
                    bytes: item.bounce_bytes,
                });
            }
            push_chunked(
                &mut plan,
                write,
                item.file,
                item.offset,
                item.staging_off,
                item.len,
                ctx.chunk_bytes,
            );
            if bounced {
                plan.push(PlanOp::Drain);
                if !write {
                    // Copy out of the aligned bounce buffer into the
                    // (unaligned) destination tensor.
                    plan.push(PlanOp::BounceCopy {
                        bytes: item.bounce_bytes,
                    });
                }
            }
            // Restore parses the header right after it arrives, before
            // payload reads are issued.
            if !write && item.is_meta {
                plan.push(PlanOp::Drain);
            }
        }
        plan.push(PlanOp::Drain);

        if write {
            for f in 0..offsets.files.len() {
                plan.push(PlanOp::Fsync { file: f });
            }
        } else {
            if shard.lean_bytes() > 0 {
                plan.push(PlanOp::Deserialize {
                    bytes: shard.lean_bytes(),
                });
            }
            if device {
                plan.push(PlanOp::H2D {
                    bytes: shard.gpu_bytes(),
                });
            }
        }
        plan
    }
}

/// A merged run of adjacent items.
struct CoalescedRun {
    file: usize,
    offset: u64,
    staging_off: u64,
    len: u64,
    /// Unaligned payload bytes requiring an O_DIRECT bounce copy
    /// (0 = aligned; coalesced runs are always aligned).
    bounce_bytes: u64,
    is_meta: bool,
}

/// Merge runs of adjacent items in the same file whose individual sizes
/// are below `threshold`. Metadata items keep their run boundary on the
/// read path (callers drain after meta), which falls out naturally
/// because a meta item ends its run.
fn coalesce_items(
    items: &[crate::ckpt::aggregation::PlacedItem],
    threshold: u64,
    write: bool,
) -> Vec<CoalescedRun> {
    let mut out: Vec<CoalescedRun> = Vec::new();
    for it in items {
        let is_meta = matches!(it.kind, ItemKind::Meta { .. });
        let small = it.padded_len < threshold;
        if let Some(last) = out.last_mut() {
            let adjacent = last.file == it.file
                && last.offset + last.len == it.offset
                && last.staging_off + last.len == it.staging_off;
            let last_extendable = !(last.is_meta && !write);
            // Cap merged runs at 64 MiB — the transfer chunk size, so
            // coalescing only ever reduces the op count.
            let cap = 64 * crate::util::bytes::MIB;
            if small && adjacent && last_extendable && last.len + it.padded_len <= cap {
                last.len += it.padded_len;
                last.is_meta = false;
                continue;
            }
        }
        out.push(CoalescedRun {
            file: it.file,
            offset: it.offset,
            staging_off: it.staging_off,
            len: it.padded_len,
            bounce_bytes: 0,
            is_meta,
        });
    }
    out
}

impl CkptEngine for UringBaseline {
    fn name(&self) -> &'static str {
        match (self.mode, self.direct) {
            (SubmitMode::Posix, true) => "posix-direct",
            (SubmitMode::Posix, false) => "posix-buffered",
            (_, true) => "uring-baseline",
            (_, false) => "uring-buffered",
        }
    }

    fn submit_mode(&self) -> SubmitMode {
        self.mode
    }

    fn plan_checkpoint(&self, shards: &[RankShard], ctx: &EngineCtx) -> Vec<RankPlan> {
        let bases = shared_file_bases(shards, ctx.align);
        shards
            .iter()
            .enumerate()
            .map(|(i, s)| self.plan_rank(s, bases[i], ctx, true))
            .collect()
    }

    fn plan_restore(&self, shards: &[RankShard], ctx: &EngineCtx) -> Vec<RankPlan> {
        let bases = shared_file_bases(shards, ctx.align);
        shards
            .iter()
            .enumerate()
            .map(|(i, s)| self.plan_rank(s, bases[i], ctx, false))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engines::testutil::{synthetic_shards, tiny_shards};
    use crate::simpfs::{SimExecutor, SimParams};

    fn ctx() -> EngineCtx {
        EngineCtx {
            chunk_bytes: crate::util::bytes::MIB,
            ..Default::default()
        }
    }

    #[test]
    fn plans_validate_for_all_aggregations() {
        let shards = tiny_shards();
        for agg in Aggregation::all() {
            let e = UringBaseline::new(agg);
            for p in e.plan_checkpoint(&shards, &ctx()) {
                p.validate().unwrap();
            }
            for p in e.plan_restore(&shards, &ctx()) {
                p.validate().unwrap();
            }
        }
    }

    #[test]
    fn checkpoint_and_restore_move_same_bytes() {
        let shards = tiny_shards();
        let e = UringBaseline::default();
        let w: u64 = e
            .plan_checkpoint(&shards, &ctx())
            .iter()
            .map(|p| p.write_bytes())
            .sum();
        let r: u64 = e
            .plan_restore(&shards, &ctx())
            .iter()
            .map(|p| p.read_bytes())
            .sum();
        assert_eq!(w, r);
        let payload: u64 = shards.iter().map(|s| s.total_bytes()).sum();
        assert!(w >= payload, "padding only adds");
        assert!(w < payload + payload / 4, "padding bounded");
    }

    #[test]
    fn shared_file_plans_run_in_sim() {
        let shards = synthetic_shards();
        let e = UringBaseline::default();
        let plans = e.plan_checkpoint(&shards, &ctx());
        let rep = SimExecutor::new(SimParams::tiny_test(), e.submit_mode())
            .run(&plans)
            .unwrap();
        assert!(rep.makespan > 0.0);
        assert_eq!(
            rep.write_bytes,
            plans.iter().map(|p| p.write_bytes() as u128).sum::<u128>()
        );
    }

    #[test]
    fn aggregated_beats_file_per_tensor_in_sim() {
        let shards = tiny_shards();
        let run = |agg| {
            let e = UringBaseline::new(agg);
            let plans = e.plan_checkpoint(&shards, &ctx());
            SimExecutor::new(SimParams::tiny_test(), e.submit_mode())
                .run(&plans)
                .unwrap()
                .makespan
        };
        let fpt = run(Aggregation::FilePerTensor);
        let shf = run(Aggregation::SharedFile);
        assert!(shf < fpt, "shared {shf} vs file-per-tensor {fpt}");
    }

    #[test]
    fn restore_has_no_alloc_ops() {
        let shards = tiny_shards();
        let plans = UringBaseline::default().plan_restore(&shards, &ctx());
        for p in &plans {
            assert!(!p.ops.iter().any(|o| matches!(o, PlanOp::Alloc { .. })));
        }
    }

    #[test]
    fn device_transfers_optional() {
        let shards = tiny_shards();
        let mut c = ctx();
        c.include_device_transfers = true;
        let plans = UringBaseline::default().plan_checkpoint(&shards, &c);
        assert!(plans[0].ops.iter().any(|o| matches!(o, PlanOp::D2H { .. })));
        let plans = UringBaseline::default().plan_checkpoint(&shards, &ctx());
        assert!(!plans[0].ops.iter().any(|o| matches!(o, PlanOp::D2H { .. })));
    }

    #[test]
    fn from_device_forces_pcie_staging() {
        // The device-tier knob puts D2H on checkpoints and H2D on
        // restores even when the ctx leaves device transfers off.
        let shards = tiny_shards();
        let e = UringBaseline::default().from_device();
        let w = e.plan_checkpoint(&shards, &ctx());
        assert!(w[0].ops.iter().any(|o| matches!(o, PlanOp::D2H { .. })));
        let r = e.plan_restore(&shards, &ctx());
        assert!(r[0].ops.iter().any(|o| matches!(o, PlanOp::H2D { .. })));
        for p in w.iter().chain(r.iter()) {
            p.validate().unwrap();
        }
    }

    #[test]
    fn tier_knob_prefixes_every_file_and_runs_in_sim() {
        let shards = synthetic_shards();
        let e = UringBaseline::new(Aggregation::FilePerProcess)
            .on_tier(crate::tier::LOCAL_TIER_PREFIX);
        let plans = e.plan_checkpoint(&shards, &ctx());
        for p in &plans {
            p.validate().unwrap();
            for f in &p.files {
                assert!(f.path.starts_with(crate::tier::LOCAL_TIER_PREFIX), "{}", f.path);
            }
        }
        // Local-tier plans must be at least as fast as PFS plans under
        // the tiny_test calibration (no NIC/OST/MDS on the path).
        let local = SimExecutor::new(SimParams::tiny_test(), e.submit_mode())
            .run(&plans)
            .unwrap();
        let pfs_plans =
            UringBaseline::new(Aggregation::FilePerProcess).plan_checkpoint(&shards, &ctx());
        let pfs = SimExecutor::new(SimParams::tiny_test(), e.submit_mode())
            .run(&pfs_plans)
            .unwrap();
        assert!(
            local.makespan < pfs.makespan,
            "local {} vs pfs {}",
            local.makespan,
            pfs.makespan
        );
    }

    #[test]
    fn stable_fraction_sheds_write_bytes_not_read_bytes() {
        let shards = tiny_shards();
        let wbytes = |f: f64| -> u64 {
            UringBaseline::default()
                .with_stable_fraction(f)
                .plan_checkpoint(&shards, &ctx())
                .iter()
                .map(|p| p.write_bytes())
                .sum()
        };
        let full = wbytes(0.0);
        let half = wbytes(0.5);
        assert_eq!(half, wbytes(0.5), "per-rank skip draw is deterministic");
        assert!(half < full, "stable chunks shed write bytes: {half} vs {full}");
        // Restores always read full state — inherited chunks come off
        // ancestor packs at the same read cost.
        let rbytes = |f: f64| -> u64 {
            UringBaseline::default()
                .with_stable_fraction(f)
                .plan_restore(&shards, &ctx())
                .iter()
                .map(|p| p.read_bytes())
                .sum()
        };
        assert_eq!(rbytes(0.9), rbytes(0.0));
        for p in UringBaseline::default()
            .with_stable_fraction(0.5)
            .plan_checkpoint(&shards, &ctx())
        {
            p.validate().unwrap();
        }
    }

    #[test]
    fn token_chain_only_when_serialized_offsets() {
        let shards = tiny_shards();
        let mut c = ctx();
        c.serialize_offsets = true;
        let plans = UringBaseline::default().plan_checkpoint(&shards, &c);
        assert!(plans[1]
            .ops
            .iter()
            .any(|o| matches!(o, PlanOp::TokenRecv { .. })));
    }
}
