//! TorchSnapshot I/O-pattern model.
//!
//! Per the paper (§2, §3.5): large objects and model states are split
//! into fixed 512 MB chunks, **each chunk flushed to a separate file in
//! a deeply nested subdirectory** — stressing MDS, OSS and OSTs alike —
//! over **libaio**, which lacks liburing's batching and queueing.
//! Device-to-host staging is synchronous. Restore first reads a single
//! manifest describing everything, then restores objects one by one with
//! one read call per object chunk, allocating as it goes.

use crate::plan::{BufSlice, FileSpec, PlanOp, RankPlan};
use crate::simpfs::exec::SubmitMode;
use crate::util::align::align_up;
use crate::util::bytes::MIB;
use crate::workload::layout::RankShard;

use super::{CkptEngine, EngineCtx};

/// TorchSnapshot model. `chunk_bytes` defaults to the engine's 512 MB.
#[derive(Debug, Clone)]
pub struct TorchSnapshot {
    pub chunk_bytes: u64,
    /// Calibrated per-chunk Python framework cost.
    pub per_chunk_us: u64,
    /// GIL-bound per-buffer handling rate on irregular LLM state
    /// (bytes/s), applied in LLM-realistic mode only (Figure 18
    /// calibration; see EXPERIMENTS.md).
    pub llm_handling_bw: f64,
}

impl Default for TorchSnapshot {
    fn default() -> Self {
        Self {
            chunk_bytes: 512 * MIB,
            per_chunk_us: 3500,
            llm_handling_bw: 1.0e9,
        }
    }
}

impl TorchSnapshot {
    /// The chunk files of one object: `(path, bytes)`, nested per the
    /// engine's `snapshot/<epoch>/rank_<r>/<object>/...` convention.
    fn chunks(&self, rank: usize, obj: &crate::ckpt::object::CkptObject) -> Vec<(String, u64)> {
        let total = obj.total_bytes();
        let mut out = Vec::new();
        let stem = obj.file_name.replace(".pt", "");
        let mut left = total;
        let mut i = 0;
        while left > 0 {
            let n = left.min(self.chunk_bytes);
            out.push((
                format!("snapshot/0/rank_{rank}/{stem}/chunk_{i:04}.data"),
                n,
            ));
            left -= n;
            i += 1;
        }
        out
    }
}

impl CkptEngine for TorchSnapshot {
    fn name(&self) -> &'static str {
        "torchsnapshot"
    }

    fn submit_mode(&self) -> SubmitMode {
        SubmitMode::Libaio
    }

    fn plan_checkpoint(&self, shards: &[RankShard], ctx: &EngineCtx) -> Vec<RankPlan> {
        shards
            .iter()
            .map(|shard| {
                let mut plan = RankPlan::new(shard.rank, ctx.node_of(shard.rank));
                // libaio: shallow queue (capped by the executor too).
                plan.push(PlanOp::QueueDepth { qd: 4 });
                if ctx.include_device_transfers {
                    // Synchronous D2H staging of the whole shard before
                    // any I/O (TorchSnapshot's sync transfer stage).
                    plan.push(PlanOp::D2H {
                        bytes: shard.gpu_bytes(),
                    });
                    if shard.lean_bytes() > 0 {
                        plan.push(PlanOp::Serialize {
                            bytes: shard.lean_bytes(),
                        });
                    }
                }
                let mut staging = 0u64;
                for obj in &shard.objects {
                    if ctx.bounce_unaligned {
                        // Per-tensor chunking of irregular LLM buffers
                        // into 512 MB chunk streams (GIL-bound).
                        plan.push(PlanOp::CpuWork {
                            us: (obj.total_bytes() as f64 / self.llm_handling_bw * 1e6)
                                as u64,
                        });
                    }
                    for (path, bytes) in self.chunks(shard.rank, obj) {
                        let padded = align_up(bytes, ctx.align);
                        let f = plan.add_file(FileSpec {
                            path,
                            direct: false, // buffered: torch writes via fwrite
                            size_hint: padded,
                            creates: true,
                        });
                        if self.per_chunk_us > 0 {
                            plan.push(PlanOp::CpuWork {
                                us: self.per_chunk_us,
                            });
                        }
                        plan.push(PlanOp::Create { file: f });
                        plan.push(PlanOp::Write {
                            file: f,
                            offset: 0,
                            src: BufSlice::new(staging, padded),
                        });
                        staging += padded;
                    }
                }
                // Manifest describing every chunk, written last.
                let manifest = plan.add_file(FileSpec {
                    path: format!("snapshot/0/rank_{}/manifest.json", shard.rank),
                    direct: false,
                    size_hint: 4096,
                    creates: true,
                });
                plan.push(PlanOp::Create { file: manifest });
                plan.push(PlanOp::Drain);
                plan.push(PlanOp::Write {
                    file: manifest,
                    offset: 0,
                    src: BufSlice::new(staging, 4096),
                });
                plan.push(PlanOp::Drain);
                for f in 0..plan.files.len() {
                    plan.push(PlanOp::Fsync { file: f });
                }
                plan
            })
            .collect()
    }

    fn plan_restore(&self, shards: &[RankShard], ctx: &EngineCtx) -> Vec<RankPlan> {
        shards
            .iter()
            .map(|shard| {
                let mut plan = RankPlan::new(shard.rank, ctx.node_of(shard.rank));
                plan.push(PlanOp::QueueDepth { qd: 1 }); // one read per object at a time
                // Read the manifest first.
                let manifest = plan.add_file(FileSpec {
                    path: format!("snapshot/0/rank_{}/manifest.json", shard.rank),
                    direct: false,
                    size_hint: 4096,
                    creates: false,
                });
                plan.push(PlanOp::Open { file: manifest });
                let mut staging = 0u64;
                plan.push(PlanOp::Read {
                    file: manifest,
                    offset: 0,
                    dst: BufSlice::new(staging, 4096),
                });
                plan.push(PlanOp::Drain);
                staging += 4096;
                // Objects one-by-one, one read per chunk file, dynamic
                // allocation per read.
                for obj in &shard.objects {
                    for (path, bytes) in self.chunks(shard.rank, obj) {
                        let padded = align_up(bytes, ctx.align);
                        let f = plan.add_file(FileSpec {
                            path,
                            direct: false,
                            size_hint: padded,
                            creates: false,
                        });
                        plan.push(PlanOp::Open { file: f });
                        if self.per_chunk_us > 0 {
                            plan.push(PlanOp::CpuWork {
                                us: self.per_chunk_us,
                            });
                        }
                        plan.push(PlanOp::Alloc { bytes: padded });
                        plan.push(PlanOp::Read {
                            file: f,
                            offset: 0,
                            dst: BufSlice::new(staging, padded),
                        });
                        plan.push(PlanOp::Drain);
                        // Decode + copy the chunk into its destination
                        // tensor storage (torch.load-style per-chunk
                        // post-processing).
                        plan.push(PlanOp::Deserialize { bytes });
                        plan.push(PlanOp::Close { file: f });
                        staging += padded;
                    }
                    if obj.lean_bytes > 0 {
                        plan.push(PlanOp::Deserialize {
                            bytes: obj.lean_bytes,
                        });
                    }
                    if ctx.include_device_transfers && obj.gpu_bytes() > 0 {
                        plan.push(PlanOp::H2D {
                            bytes: obj.gpu_bytes(),
                        });
                    }
                }
                plan
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engines::testutil::tiny_shards;
    use crate::simpfs::{SimExecutor, SimParams};
    use crate::util::bytes::GIB;

    fn ctx() -> EngineCtx {
        EngineCtx::default()
    }

    #[test]
    fn plans_validate() {
        let shards = tiny_shards();
        let e = TorchSnapshot::default();
        for p in e
            .plan_checkpoint(&shards, &ctx())
            .iter()
            .chain(e.plan_restore(&shards, &ctx()).iter())
        {
            p.validate().unwrap();
        }
    }

    #[test]
    fn large_objects_split_into_512mb_chunks() {
        use crate::ckpt::object::{CkptObject, Residence, TensorSpec};
        use crate::workload::modelspec::DType;
        let e = TorchSnapshot::default();
        let obj = CkptObject::new(
            "optim.pt",
            vec![TensorSpec::new(
                "big",
                vec![(3 * GIB) / 4 + 1000],
                DType::F32,
                Residence::Gpu,
            )],
            0,
        );
        let chunks = e.chunks(0, &obj);
        assert_eq!(chunks.len(), 7, "3 GiB + ε → 7 × 512 MiB chunks");
        assert!(chunks[0].0.contains("rank_0/optim/chunk_0000"));
        assert!(chunks.iter().take(6).all(|c| c.1 == 512 * MIB));
    }

    #[test]
    fn nested_directory_layout() {
        let shards = tiny_shards();
        let plans = TorchSnapshot::default().plan_checkpoint(&shards, &ctx());
        for p in &plans {
            for f in &p.files {
                assert!(
                    f.path.starts_with("snapshot/0/rank_"),
                    "nested path: {}",
                    f.path
                );
                assert!(f.path.matches('/').count() >= 3);
            }
        }
    }

    #[test]
    fn more_files_than_datastates() {
        let shards = tiny_shards();
        let ts: usize = TorchSnapshot::default()
            .plan_checkpoint(&shards, &ctx())
            .iter()
            .map(|p| p.files.len())
            .sum();
        let ds: usize = crate::engines::DataStatesLlm::default()
            .plan_checkpoint(&shards, &ctx())
            .iter()
            .map(|p| p.files.len())
            .sum();
        assert!(ts > ds, "torchsnapshot {ts} files vs datastates {ds}");
    }

    #[test]
    fn slower_than_baseline_in_sim() {
        let shards = tiny_shards();
        let ts = TorchSnapshot::default();
        let base = crate::engines::UringBaseline::default();
        let c = EngineCtx {
            chunk_bytes: crate::util::bytes::MIB,
            ..Default::default()
        };
        let run = |plans: Vec<crate::plan::RankPlan>, mode| {
            SimExecutor::new(SimParams::tiny_test(), mode)
                .run(&plans)
                .unwrap()
                .makespan
        };
        let t_ts = run(ts.plan_checkpoint(&shards, &c), ts.submit_mode());
        let t_b = run(base.plan_checkpoint(&shards, &c), base.submit_mode());
        assert!(t_ts > t_b, "torchsnapshot {t_ts} vs baseline {t_b}");
    }
}
