//! DataStates-LLM I/O-pattern model.
//!
//! Faithful to the engine's documented behaviour (paper §2, §3.5):
//!
//! * **File-per-shard layout** — one file per logical checkpoint object
//!   (the N·M DeepSpeed layout), liburing backend.
//! * **Submit-on-ready** — objects are staged (D2H) one at a time and
//!   their writes are submitted as soon as each object is available,
//!   rather than accumulating into large batches; flushes overlap the
//!   next object's staging.
//! * **Restore triples read counts** — one read for the metadata, one
//!   for the lean object, one per tensor; host memory for every read is
//!   **allocated on the fly** (the Figure 13 bottleneck), and objects
//!   restore strictly serially.

use crate::plan::{BufSlice, FileSpec, PlanOp, RankPlan};
use crate::simpfs::exec::SubmitMode;
use crate::util::align::align_up;
use crate::workload::layout::RankShard;

use super::{push_chunked, CkptEngine, EngineCtx};

/// DataStates-LLM model. `alloc_per_read` exists so Figure 14 can show
/// the counterfactual (allocation removed). `per_item_us` is the
/// calibrated Python-side per-item framework cost (object handling,
/// pinning, metadata bookkeeping under the GIL) behind the engine gaps
/// of Figures 11/18.
#[derive(Debug, Clone)]
pub struct DataStatesLlm {
    pub alloc_per_read: bool,
    pub per_item_us: u64,
    /// GIL-bound per-buffer handling rate on irregular LLM state
    /// (bytes/s): pinned-block chunking + bookkeeping per tensor.
    /// Applied only in LLM-realistic mode (ctx.bounce_unaligned);
    /// contiguous synthetic buffers stage at full memcpy speed.
    /// Calibrated from the paper's Figure 18 gaps (see EXPERIMENTS.md).
    pub llm_handling_bw: f64,
    /// Cascade-targeting knob: place every object file under this tier
    /// prefix (e.g. [`crate::tier::LOCAL_TIER_PREFIX`] stages the
    /// flushes into the burst-buffer tier — DataStates-LLM's lazy
    /// multi-level pattern).
    pub tier_prefix: Option<String>,
    /// Source plans from the device tier: per-object D2H staging on
    /// checkpoints and H2D placement on restores, regardless of
    /// `EngineCtx::include_device_transfers` — the cascade's tier-0
    /// lifecycle (device → host → storage).
    pub from_device: bool,
}

impl Default for DataStatesLlm {
    fn default() -> Self {
        Self {
            alloc_per_read: true,
            per_item_us: 1800,
            llm_handling_bw: 1.5e9,
            tier_prefix: None,
            from_device: false,
        }
    }
}

impl DataStatesLlm {
    fn handling_us(&self, bytes: u64) -> u64 {
        (bytes as f64 / self.llm_handling_bw * 1e6) as u64
    }

    /// The Figure 14 variant: identical I/O, no dynamic allocation.
    pub fn without_alloc() -> Self {
        Self {
            alloc_per_read: false,
            ..Default::default()
        }
    }

    /// Target the plans at a cascade tier (see `tier_prefix`).
    pub fn on_tier(mut self, prefix: impl Into<String>) -> Self {
        self.tier_prefix = Some(prefix.into());
        self
    }

    /// Source plans from the device tier (see `from_device`).
    pub fn from_device(mut self) -> Self {
        self.from_device = true;
        self
    }

    fn object_path(rank: usize, name: &str) -> String {
        format!("rank{rank:03}/{name}")
    }

    /// Per-object region layout within its file: meta | lean | tensors,
    /// each aligned.
    fn object_extents(
        obj: &crate::ckpt::object::CkptObject,
        align: u64,
    ) -> (u64, u64, Vec<u64>, u64) {
        let meta_len = align_up(4096.max(obj.tensors.len() as u64 * 92 + 64), align);
        let lean_len = if obj.lean_bytes > 0 {
            align_up(obj.lean_bytes, align)
        } else {
            0
        };
        let mut tensor_offs = Vec::with_capacity(obj.tensors.len());
        let mut cursor = meta_len + lean_len;
        for t in &obj.tensors {
            tensor_offs.push(cursor);
            cursor += align_up(t.bytes(), align);
        }
        (meta_len, lean_len, tensor_offs, cursor)
    }
}

impl CkptEngine for DataStatesLlm {
    fn name(&self) -> &'static str {
        if self.alloc_per_read {
            "datastates-llm"
        } else {
            "datastates-llm (no alloc)"
        }
    }

    fn submit_mode(&self) -> SubmitMode {
        SubmitMode::Uring
    }

    fn plan_checkpoint(&self, shards: &[RankShard], ctx: &EngineCtx) -> Vec<RankPlan> {
        shards
            .iter()
            .map(|shard| {
                let mut plan = RankPlan::new(shard.rank, ctx.node_of(shard.rank));
                // Moderate queue depth: submissions happen per object,
                // so the ring rarely fills anyway.
                plan.push(PlanOp::QueueDepth {
                    qd: ctx.queue_depth.min(16),
                });
                let mut staging = 0u64;
                for obj in &shard.objects {
                    let (meta_len, lean_len, tensor_offs, extent) =
                        Self::object_extents(obj, ctx.align);
                    let f = plan.add_file(FileSpec {
                        path: super::tier_join(
                            &self.tier_prefix,
                            &Self::object_path(shard.rank, &obj.file_name),
                        ),
                        direct: true,
                        size_hint: extent,
                        creates: true,
                    });
                    plan.push(PlanOp::Create { file: f });
                    if self.from_device || ctx.include_device_transfers {
                        // Lean-object serialization is the synchronous
                        // stage (GIL-bound), then the object's tensors
                        // stage to host; flushes of this object overlap
                        // the next object's staging (async writes).
                        if obj.lean_bytes > 0 {
                            plan.push(PlanOp::Serialize {
                                bytes: obj.lean_bytes,
                            });
                        }
                        if obj.gpu_bytes() > 0 {
                            plan.push(PlanOp::D2H {
                                bytes: obj.gpu_bytes(),
                            });
                        }
                        let host = obj.total_bytes() - obj.gpu_bytes();
                        if host > 0 {
                            plan.push(PlanOp::StagingCopy { bytes: host });
                        }
                        if ctx.bounce_unaligned {
                            // GIL-bound per-tensor chunking of irregular
                            // LLM buffers into pinned blocks happens on
                            // the GPU staging path too.
                            plan.push(PlanOp::CpuWork {
                                us: self.handling_us(obj.total_bytes()),
                            });
                        }
                    } else if ctx.bounce_unaligned {
                        // Irregular LLM buffers: GIL-bound per-tensor
                        // chunking into pinned blocks (the dominant
                        // framework cost of Figure 18).
                        plan.push(PlanOp::CpuWork {
                            us: self.handling_us(obj.total_bytes()),
                        });
                    } else {
                        // Host-resident contiguous objects are still
                        // copied into the engine's pinned staging
                        // buffers before their writes are submitted —
                        // the framework overhead behind the ~1.2x gap
                        // of Figure 11.
                        plan.push(PlanOp::StagingCopy {
                            bytes: obj.total_bytes(),
                        });
                    }
                    // Submit-on-ready: header + lean + tensors of THIS
                    // object go out now (no cross-object batching).
                    plan.push(PlanOp::Write {
                        file: f,
                        offset: 0,
                        src: BufSlice::new(staging, meta_len),
                    });
                    let mut stage_cursor = staging + meta_len;
                    if lean_len > 0 {
                        plan.push(PlanOp::Write {
                            file: f,
                            offset: meta_len,
                            src: BufSlice::new(stage_cursor, lean_len),
                        });
                        stage_cursor += lean_len;
                    }
                    for (t, off) in obj.tensors.iter().zip(&tensor_offs) {
                        let padded = align_up(t.bytes(), ctx.align);
                        if self.per_item_us > 0 {
                            plan.push(PlanOp::CpuWork {
                                us: self.per_item_us,
                            });
                        }
                        push_chunked(
                            &mut plan,
                            true,
                            f,
                            *off,
                            stage_cursor,
                            padded,
                            ctx.chunk_bytes,
                        );
                        stage_cursor += padded;
                    }
                    staging = stage_cursor;
                }
                plan.push(PlanOp::Drain);
                for f in 0..plan.files.len() {
                    plan.push(PlanOp::Fsync { file: f });
                }
                plan
            })
            .collect()
    }

    fn plan_restore(&self, shards: &[RankShard], ctx: &EngineCtx) -> Vec<RankPlan> {
        shards
            .iter()
            .map(|shard| {
                let mut plan = RankPlan::new(shard.rank, ctx.node_of(shard.rank));
                // Paper §2: all engines restore with a synchronous and
                // serial read approach — one data structure at a time,
                // the next file only when the previous object is fully
                // restored.
                plan.push(PlanOp::QueueDepth { qd: 1 });
                let mut staging = 0u64;
                for obj in &shard.objects {
                    let (meta_len, lean_len, tensor_offs, extent) =
                        Self::object_extents(obj, ctx.align);
                    let f = plan.add_file(FileSpec {
                        path: super::tier_join(
                            &self.tier_prefix,
                            &Self::object_path(shard.rank, &obj.file_name),
                        ),
                        direct: true,
                        size_hint: extent,
                        creates: false,
                    });
                    plan.push(PlanOp::Open { file: f });
                    // Read 1: metadata header (a few KB) — must complete
                    // before anything else is known.
                    if self.alloc_per_read {
                        plan.push(PlanOp::Alloc { bytes: meta_len });
                    }
                    plan.push(PlanOp::Read {
                        file: f,
                        offset: 0,
                        dst: BufSlice::new(staging, meta_len),
                    });
                    plan.push(PlanOp::Drain);
                    let mut stage_cursor = staging + meta_len;
                    // Read 2: the lean object, then deserialize it.
                    if lean_len > 0 {
                        if self.alloc_per_read {
                            plan.push(PlanOp::Alloc { bytes: lean_len });
                        }
                        plan.push(PlanOp::Read {
                            file: f,
                            offset: meta_len,
                            dst: BufSlice::new(stage_cursor, lean_len),
                        });
                        plan.push(PlanOp::Drain);
                        plan.push(PlanOp::Deserialize {
                            bytes: obj.lean_bytes,
                        });
                        stage_cursor += lean_len;
                    }
                    // Read 3..: one per tensor, allocating on the fly.
                    // Strictly serial: the next data structure is read
                    // only once the previous one landed (paper §2).
                    for (t, off) in obj.tensors.iter().zip(&tensor_offs) {
                        let padded = align_up(t.bytes(), ctx.align);
                        if self.per_item_us > 0 {
                            plan.push(PlanOp::CpuWork {
                                us: self.per_item_us,
                            });
                        }
                        if self.alloc_per_read {
                            plan.push(PlanOp::Alloc { bytes: padded });
                        }
                        push_chunked(
                            &mut plan,
                            false,
                            f,
                            *off,
                            stage_cursor,
                            padded,
                            ctx.chunk_bytes,
                        );
                        plan.push(PlanOp::Drain);
                        stage_cursor += padded;
                    }
                    if ctx.bounce_unaligned {
                        // Per-tensor placement of irregular buffers
                        // (GIL-bound copy-out of pinned blocks).
                        plan.push(PlanOp::CpuWork {
                            us: self.handling_us(obj.total_bytes()),
                        });
                    }
                    // Object fully restored (incl. H2D) before the next.
                    plan.push(PlanOp::Drain);
                    if (self.from_device || ctx.include_device_transfers) && obj.gpu_bytes() > 0 {
                        plan.push(PlanOp::H2D {
                            bytes: obj.gpu_bytes(),
                        });
                    }
                    plan.push(PlanOp::Close { file: f });
                    staging = stage_cursor;
                }
                plan
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engines::testutil::tiny_shards;
    use crate::simpfs::{SimExecutor, SimParams};

    fn ctx() -> EngineCtx {
        EngineCtx {
            chunk_bytes: crate::util::bytes::MIB,
            ..Default::default()
        }
    }

    #[test]
    fn plans_validate() {
        let shards = tiny_shards();
        let e = DataStatesLlm::default();
        for p in e
            .plan_checkpoint(&shards, &ctx())
            .iter()
            .chain(e.plan_restore(&shards, &ctx()).iter())
        {
            p.validate().unwrap();
        }
    }

    #[test]
    fn file_per_object_layout() {
        let shards = tiny_shards();
        let plans = DataStatesLlm::default().plan_checkpoint(&shards, &ctx());
        for (p, s) in plans.iter().zip(&shards) {
            assert_eq!(p.files.len(), s.objects.len(), "one file per object");
        }
    }

    #[test]
    fn restore_triples_read_count() {
        // Paper: one read for metadata + one for lean + one per tensor.
        let shards = tiny_shards();
        let plans = DataStatesLlm::default().plan_restore(&shards, &ctx());
        let c = ctx();
        for (p, s) in plans.iter().zip(&shards) {
            let min_reads: usize = s
                .objects
                .iter()
                .map(|o| 1 + usize::from(o.lean_bytes > 0) + o.tensors.len())
                .sum();
            // Chunking can only increase the count.
            assert!(
                p.transfer_ops() >= min_reads,
                "reads {} < minimum {min_reads} (chunk {})",
                p.transfer_ops(),
                c.chunk_bytes,
            );
        }
    }

    #[test]
    fn alloc_dominated_restore_vs_no_alloc() {
        // Figures 13–14: removing per-read allocation nearly doubles
        // restore throughput.
        let shards = tiny_shards();
        let with_alloc = DataStatesLlm::default();
        let without = DataStatesLlm::without_alloc();
        let run = |e: &DataStatesLlm| {
            let plans = e.plan_restore(&shards, &ctx());
            SimExecutor::new(SimParams::tiny_test(), e.submit_mode())
                .run(&plans)
                .unwrap()
        };
        let a = run(&with_alloc);
        let b = run(&without);
        assert!(
            a.makespan > b.makespan * 1.3,
            "alloc {} vs none {}",
            a.makespan,
            b.makespan
        );
        assert!(a.phase_total("alloc") > 0.0);
        assert_eq!(b.phase_total("alloc"), 0.0);
    }

    #[test]
    fn from_device_forces_per_object_staging() {
        let shards = tiny_shards();
        let e = DataStatesLlm::default().from_device();
        let w = e.plan_checkpoint(&shards, &ctx());
        assert!(w[0].ops.iter().any(|o| matches!(o, PlanOp::D2H { .. })));
        let r = e.plan_restore(&shards, &ctx());
        assert!(r[0].ops.iter().any(|o| matches!(o, PlanOp::H2D { .. })));
        for p in w.iter().chain(r.iter()) {
            p.validate().unwrap();
        }
    }

    #[test]
    fn checkpoint_restore_byte_symmetry() {
        let shards = tiny_shards();
        let e = DataStatesLlm::default();
        let w: u64 = e
            .plan_checkpoint(&shards, &ctx())
            .iter()
            .map(|p| p.write_bytes())
            .sum();
        let r: u64 = e
            .plan_restore(&shards, &ctx())
            .iter()
            .map(|p| p.read_bytes())
            .sum();
        assert_eq!(w, r);
    }
}
