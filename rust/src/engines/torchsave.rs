//! Default `torch.save` I/O-pattern model (DeepSpeed's default engine).
//!
//! Per the paper §2: for each logical object, `torch.save` synchronously
//! and sequentially allocates host memory, transfers GPU structures to
//! host, pickles the *entire* object (tensors included — no detaching),
//! and flushes the serialized stream through a single buffered write.
//! Restore (`torch.load`) reads and unpickles the whole object, then
//! moves structures back to the GPU. Everything blocks; nothing batches.

use crate::plan::{BufSlice, FileSpec, PlanOp, RankPlan};
use crate::simpfs::exec::SubmitMode;
use crate::util::align::align_up;
use crate::workload::layout::RankShard;

use super::{CkptEngine, EngineCtx};

#[derive(Debug, Clone, Default)]
pub struct TorchSave;

impl TorchSave {
    fn path(rank: usize, name: &str) -> String {
        format!("rank{rank:03}/{name}")
    }
}

impl CkptEngine for TorchSave {
    fn name(&self) -> &'static str {
        "torch.save"
    }

    fn submit_mode(&self) -> SubmitMode {
        SubmitMode::Posix
    }

    fn plan_checkpoint(&self, shards: &[RankShard], ctx: &EngineCtx) -> Vec<RankPlan> {
        shards
            .iter()
            .map(|shard| {
                let mut plan = RankPlan::new(shard.rank, ctx.node_of(shard.rank));
                plan.push(PlanOp::QueueDepth { qd: 1 });
                let mut staging = 0u64;
                for obj in &shard.objects {
                    let total = align_up(obj.total_bytes(), ctx.align);
                    let f = plan.add_file(FileSpec {
                        path: Self::path(shard.rank, &obj.file_name),
                        direct: false, // buffered python file I/O
                        size_hint: total,
                        creates: true,
                    });
                    // Allocate a fresh host buffer for the object, move
                    // GPU data over, pickle EVERYTHING (the expensive
                    // part: tensors are serialized too).
                    plan.push(PlanOp::Alloc { bytes: total });
                    if ctx.include_device_transfers && obj.gpu_bytes() > 0 {
                        plan.push(PlanOp::D2H {
                            bytes: obj.gpu_bytes(),
                        });
                    }
                    plan.push(PlanOp::Serialize {
                        bytes: obj.total_bytes(),
                    });
                    plan.push(PlanOp::Create { file: f });
                    // One sequential buffered stream write.
                    plan.push(PlanOp::Write {
                        file: f,
                        offset: 0,
                        src: BufSlice::new(staging, total),
                    });
                    plan.push(PlanOp::Drain);
                    plan.push(PlanOp::Fsync { file: f });
                    staging += total;
                }
                plan
            })
            .collect()
    }

    fn plan_restore(&self, shards: &[RankShard], ctx: &EngineCtx) -> Vec<RankPlan> {
        shards
            .iter()
            .map(|shard| {
                let mut plan = RankPlan::new(shard.rank, ctx.node_of(shard.rank));
                plan.push(PlanOp::QueueDepth { qd: 1 });
                let mut staging = 0u64;
                for obj in &shard.objects {
                    let total = align_up(obj.total_bytes(), ctx.align);
                    let f = plan.add_file(FileSpec {
                        path: Self::path(shard.rank, &obj.file_name),
                        direct: false,
                        size_hint: total,
                        creates: false,
                    });
                    plan.push(PlanOp::Open { file: f });
                    // Opaque torch.load: allocate for the whole object,
                    // read it, unpickle it all, push back to device.
                    plan.push(PlanOp::Alloc { bytes: total });
                    plan.push(PlanOp::Read {
                        file: f,
                        offset: 0,
                        dst: BufSlice::new(staging, total),
                    });
                    plan.push(PlanOp::Drain);
                    plan.push(PlanOp::Deserialize {
                        bytes: obj.total_bytes(),
                    });
                    if ctx.include_device_transfers && obj.gpu_bytes() > 0 {
                        plan.push(PlanOp::H2D {
                            bytes: obj.gpu_bytes(),
                        });
                    }
                    plan.push(PlanOp::Close { file: f });
                    staging += total;
                }
                plan
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engines::testutil::tiny_shards;
    use crate::simpfs::{SimExecutor, SimParams};

    fn ctx() -> EngineCtx {
        EngineCtx {
            include_device_transfers: true,
            chunk_bytes: crate::util::bytes::MIB,
            ..Default::default()
        }
    }

    #[test]
    fn plans_validate() {
        let shards = tiny_shards();
        let e = TorchSave;
        for p in e
            .plan_checkpoint(&shards, &ctx())
            .iter()
            .chain(e.plan_restore(&shards, &ctx()).iter())
        {
            p.validate().unwrap();
        }
    }

    #[test]
    fn serializes_full_object_bytes() {
        let shards = tiny_shards();
        let plans = TorchSave.plan_checkpoint(&shards, &ctx());
        for (p, s) in plans.iter().zip(&shards) {
            let serialized: u64 = p
                .ops
                .iter()
                .map(|op| match op {
                    PlanOp::Serialize { bytes } => *bytes,
                    _ => 0,
                })
                .sum();
            assert_eq!(serialized, s.total_bytes(), "pickles tensors too");
        }
    }

    #[test]
    fn slowest_engine_in_sim() {
        // Figure 3's ordering: ideal < DataStates < torch.save. The
        // "ideal approach" flushes host-resident buffers (no device
        // transfers); the engines run their full pipelines.
        let shards = tiny_shards();
        let c = ctx();
        let ideal_ctx = EngineCtx {
            include_device_transfers: false,
            ..c.clone()
        };
        let run = |plans: Vec<crate::plan::RankPlan>, mode| {
            SimExecutor::new(SimParams::tiny_test(), mode)
                .run(&plans)
                .unwrap()
                .makespan
        };
        let ts = TorchSave;
        let ds = crate::engines::DataStatesLlm::default();
        let base = crate::engines::UringBaseline::default();
        let t_save = run(ts.plan_checkpoint(&shards, &c), ts.submit_mode());
        let t_ds = run(ds.plan_checkpoint(&shards, &c), ds.submit_mode());
        let t_base = run(base.plan_checkpoint(&shards, &ideal_ctx), base.submit_mode());
        assert!(t_save > t_ds, "torch.save {t_save} vs datastates {t_ds}");
        assert!(t_ds > t_base, "datastates {t_ds} vs baseline {t_base}");
    }

    #[test]
    fn restore_reads_everything_serially() {
        let shards = tiny_shards();
        let plans = TorchSave.plan_restore(&shards, &ctx());
        for p in &plans {
            // qd is forced to 1 and each object drains before the next.
            assert!(p.ops.iter().any(|o| matches!(o, PlanOp::QueueDepth { qd: 1 })));
            let allocs = p
                .ops
                .iter()
                .filter(|o| matches!(o, PlanOp::Alloc { .. }))
                .count();
            assert_eq!(allocs, p.files.len());
        }
    }
}
