//! PJRT runtime: load and execute the AOT-lowered JAX/Pallas artifacts.
//!
//! `make artifacts` (build time, Python) leaves `artifacts/` with, per
//! model variant, HLO **text** for `init` and `train_step` plus a JSON
//! manifest describing the flat-parameter ABI. This module is the only
//! consumer: it compiles the HLO on the PJRT CPU client once and then
//! executes it from the Rust hot path — Python is never invoked again.

pub mod manifest;
pub mod model;

pub use manifest::{Manifest, ParamSpec};
pub use model::ModelRuntime;
