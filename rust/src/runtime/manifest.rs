//! The artifact manifest: the flat-parameter ABI with the L2 model.

use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::util::json::Json;

/// One parameter's name and shape (row-major f32).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl ParamSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
    pub fn bytes(&self) -> usize {
        self.elements() * 4
    }
}

/// Parsed `model_<variant>.manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub variant: String,
    pub params: Vec<ParamSpec>,
    pub param_count: u64,
    pub batch: usize,
    pub seq_len: usize,
    pub vocab: usize,
    pub step_outputs: usize,
    pub init_hlo: PathBuf,
    pub step_hlo: PathBuf,
}

impl Manifest {
    /// Load `artifacts/model_<variant>.manifest.json`.
    pub fn load(artifacts_dir: &Path, variant: &str) -> Result<Self> {
        let path = artifacts_dir.join(format!("model_{variant}.manifest.json"));
        let text = std::fs::read_to_string(&path)
            .map_err(|e| Error::Runtime(format!("read {}: {e}", path.display())))?;
        let j = Json::parse(&text).map_err(|e| Error::Runtime(format!("manifest: {e}")))?;
        let s = |k: &str| -> Result<String> {
            j.get(k)
                .and_then(Json::as_str)
                .map(String::from)
                .ok_or_else(|| Error::Runtime(format!("manifest missing {k}")))
        };
        let cfg = j
            .get("config")
            .ok_or_else(|| Error::Runtime("manifest missing config".into()))?;
        let cfg_u = |k: &str| -> Result<usize> {
            cfg.get(k)
                .and_then(Json::as_u64)
                .map(|x| x as usize)
                .ok_or_else(|| Error::Runtime(format!("manifest missing config.{k}")))
        };
        let params = j
            .get("params")
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::Runtime("manifest missing params".into()))?
            .iter()
            .map(|p| -> Result<ParamSpec> {
                Ok(ParamSpec {
                    name: p
                        .get("name")
                        .and_then(Json::as_str)
                        .ok_or_else(|| Error::Runtime("param missing name".into()))?
                        .to_string(),
                    shape: p
                        .get("shape")
                        .and_then(Json::as_arr)
                        .ok_or_else(|| Error::Runtime("param missing shape".into()))?
                        .iter()
                        .map(|d| d.as_u64().unwrap_or(0) as usize)
                        .collect(),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let arts = j
            .get("artifacts")
            .ok_or_else(|| Error::Runtime("manifest missing artifacts".into()))?;
        let art = |k: &str| -> Result<PathBuf> {
            Ok(artifacts_dir.join(arts.get(k).and_then(Json::as_str).ok_or_else(|| {
                Error::Runtime(format!("manifest missing artifacts.{k}"))
            })?))
        };
        Ok(Self {
            variant: s("variant")?,
            param_count: j
                .get("param_count")
                .and_then(Json::as_u64)
                .ok_or_else(|| Error::Runtime("manifest missing param_count".into()))?,
            batch: cfg_u("batch")?,
            seq_len: cfg_u("seq_len")?,
            vocab: cfg_u("vocab")?,
            step_outputs: j
                .get("step_outputs")
                .and_then(Json::as_u64)
                .ok_or_else(|| Error::Runtime("manifest missing step_outputs".into()))?
                as usize,
            init_hlo: art("init")?,
            step_hlo: art("step")?,
            params,
        })
    }

    /// Total parameter bytes (f32).
    pub fn param_bytes(&self) -> u64 {
        self.params.iter().map(|p| p.bytes() as u64).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn load_tiny_manifest_if_built() {
        let dir = artifacts_dir();
        if !dir.join("model_tiny.manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let m = Manifest::load(&dir, "tiny").unwrap();
        assert_eq!(m.variant, "tiny");
        assert_eq!(m.params[0].name, "embed");
        assert_eq!(
            m.param_count,
            m.params.iter().map(|p| p.elements() as u64).sum::<u64>()
        );
        assert_eq!(m.step_outputs, 1 + 2 * m.params.len());
        assert!(m.init_hlo.exists());
        assert!(m.step_hlo.exists());
    }

    #[test]
    fn missing_manifest_is_runtime_error() {
        let err = Manifest::load(&artifacts_dir(), "nonexistent").unwrap_err();
        assert!(err.to_string().contains("runtime"));
    }
}
