//! The model runtime: compiled init/step executables + training state.
//!
//! State lives as PJRT buffers between steps (`execute_b`), so the hot
//! loop never round-trips through host `Literal`s; conversions happen
//! only at checkpoint boundaries, where the coordinator needs the raw
//! bytes anyway.

use std::path::Path;

use xla::{HloModuleProto, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use crate::error::{Error, Result};
use crate::util::prng::Xoshiro256;

use super::manifest::Manifest;

fn xe(e: xla::Error) -> Error {
    Error::Runtime(e.to_string())
}

/// The process-wide PJRT CPU client.
///
/// xla_extension 0.5.1's TfrtCpuClient tolerates exactly one live client
/// per process — creating a second (even after dropping the first)
/// segfaults. All runtimes therefore share this leaked singleton. The
/// wrapper is `Send+Sync` because every access is serialized through the
/// mutex; the underlying `Rc` refcounts are only touched under the lock.
struct ClientCell(PjRtClient);
// SAFETY: see above — all access is mutex-serialized.
unsafe impl Send for ClientCell {}
unsafe impl Sync for ClientCell {}

static GLOBAL_CLIENT: once_cell::sync::Lazy<std::sync::Mutex<ClientCell>> =
    once_cell::sync::Lazy::new(|| {
        std::sync::Mutex::new(ClientCell(
            PjRtClient::cpu().expect("PJRT CPU client creation failed"),
        ))
    });

/// Run `f` with the process-wide PJRT client.
pub fn with_client<T>(f: impl FnOnce(&PjRtClient) -> T) -> T {
    let guard = GLOBAL_CLIENT.lock().unwrap_or_else(|e| e.into_inner());
    f(&guard.0)
}

/// Training state: parameters then momenta, as device buffers.
pub struct TrainState {
    /// `params[i]` then `moms[i]`, in manifest order.
    pub buffers: Vec<PjRtBuffer>,
    pub step: u64,
    pub last_loss: f32,
    /// Source literals of host-uploaded buffers. TfrtCpuClient's
    /// `BufferFromHostLiteral` copies asynchronously: the literal must
    /// outlive the copy, so uploads park their literals here until the
    /// next synchronizing operation retires them. Held, never read.
    #[allow(dead_code)]
    host_keepalive: Vec<Literal>,
}

/// A loaded model variant (executables compiled on the global client).
pub struct ModelRuntime {
    pub manifest: Manifest,
    init: PjRtLoadedExecutable,
    step: PjRtLoadedExecutable,
}

impl ModelRuntime {
    /// Load and compile the artifacts of `variant` from `artifacts_dir`.
    pub fn load(artifacts_dir: &Path, variant: &str) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir, variant)?;
        let (init, step) = with_client(|client| -> Result<_> {
            let compile = |path: &Path| -> Result<PjRtLoadedExecutable> {
                let proto = HloModuleProto::from_text_file(
                    path.to_str()
                        .ok_or_else(|| Error::Runtime("non-utf8 path".into()))?,
                )
                .map_err(xe)?;
                client
                    .compile(&XlaComputation::from_proto(&proto))
                    .map_err(xe)
            };
            Ok((compile(&manifest.init_hlo)?, compile(&manifest.step_hlo)?))
        })?;
        Ok(Self {
            manifest,
            init,
            step,
        })
    }

    /// Run the init executable → fresh TrainState (momenta zeroed).
    pub fn init_state(&self) -> Result<TrainState> {
        let outs = self.init.execute::<Literal>(&[]).map_err(xe)?;
        let row = outs
            .into_iter()
            .next()
            .ok_or_else(|| Error::Runtime("init: no outputs".into()))?;
        // The lowering uses return_tuple=True, so a single tuple buffer
        // comes back; decompose via literal.
        let mut buffers = Vec::new();
        let mut keepalive = Vec::new();
        if row.len() == 1 && self.manifest.params.len() > 1 {
            let lit = row[0].to_literal_sync().map_err(xe)?;
            for l in lit.to_tuple().map_err(xe)? {
                buffers.push(self.buffer_from_literal(&l)?);
                keepalive.push(l);
            }
        } else {
            buffers = row;
        }
        if buffers.len() != self.manifest.params.len() {
            return Err(Error::Runtime(format!(
                "init returned {} buffers, manifest has {} params",
                buffers.len(),
                self.manifest.params.len()
            )));
        }
        // Zero momenta with matching shapes.
        for spec in self.manifest.params.clone() {
            let zeros = vec![0f32; spec.elements()];
            let (buf, lit) = self.buffer_from_f32(&zeros, &spec.shape)?;
            buffers.push(buf);
            keepalive.push(lit);
        }
        Ok(TrainState {
            buffers,
            step: 0,
            last_loss: f32::NAN,
            host_keepalive: keepalive,
        })
    }

    /// Upload a literal and block until the async host copy lands.
    ///
    /// Perf note (§Perf iteration L3.1): removing this sync and relying
    /// on `host_keepalive` alone was tried and REVERTED — TfrtCpuClient
    /// still segfaults under test-harness thread interleavings, and the
    /// measured step-time delta was within noise (uploads are off the
    /// steady-state hot path: execute_b feeds outputs back as buffers).
    fn buffer_from_literal(&self, lit: &Literal) -> Result<PjRtBuffer> {
        let buf = with_client(|c| c.buffer_from_host_literal(None, lit)).map_err(xe)?;
        let _ = buf.to_literal_sync().map_err(xe)?;
        Ok(buf)
    }

    fn buffer_from_f32(&self, data: &[f32], shape: &[usize]) -> Result<(PjRtBuffer, Literal)> {
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        let lit = Literal::vec1(data).reshape(&dims).map_err(xe)?;
        let buf = self.buffer_from_literal(&lit)?;
        Ok((buf, lit))
    }

    /// Build an int32 token batch buffer from raw values. The returned
    /// literal must outlive the buffer's first use (async host copy).
    pub fn token_buffer(&self, tokens: &[i32]) -> Result<(PjRtBuffer, Literal)> {
        let m = &self.manifest;
        if tokens.len() != m.batch * m.seq_len {
            return Err(Error::Runtime(format!(
                "tokens {} != batch*seq {}",
                tokens.len(),
                m.batch * m.seq_len
            )));
        }
        let lit = Literal::vec1(tokens)
            .reshape(&[m.batch as i64, m.seq_len as i64])
            .map_err(xe)?;
        let buf = self.buffer_from_literal(&lit)?;
        Ok((buf, lit))
    }

    /// One training step: consumes the state, returns the updated state.
    pub fn train_step(
        &self,
        state: TrainState,
        tokens: &PjRtBuffer,
        targets: &PjRtBuffer,
    ) -> Result<TrainState> {
        let n = self.manifest.params.len();
        let next_step = state.step + 1;
        let mut args: Vec<&PjRtBuffer> = Vec::with_capacity(2 * n + 2);
        args.extend(state.buffers.iter());
        args.push(tokens);
        args.push(targets);
        let outs = self.step.execute_b(&args).map_err(xe)?;
        let row = outs
            .into_iter()
            .next()
            .ok_or_else(|| Error::Runtime("step: no outputs".into()))?;
        // With return_tuple=True the result is one tuple buffer.
        let buffers: Vec<PjRtBuffer>;
        let mut keepalive: Vec<Literal> = Vec::new();
        let loss;
        if row.len() == 1 {
            let lit = row[0].to_literal_sync().map_err(xe)?;
            let elems = lit.to_tuple().map_err(xe)?;
            if elems.len() != self.manifest.step_outputs {
                return Err(Error::Runtime(format!(
                    "step returned {} outputs, expected {}",
                    elems.len(),
                    self.manifest.step_outputs
                )));
            }
            loss = elems[0].to_vec::<f32>().map_err(xe)?[0];
            let mut bufs = Vec::with_capacity(elems.len() - 1);
            let mut it = elems.into_iter();
            let _loss_lit = it.next();
            for l in it {
                bufs.push(self.buffer_from_literal(&l)?);
                keepalive.push(l);
            }
            buffers = bufs;
        } else {
            let mut it = row.into_iter();
            let loss_buf = it.next().unwrap();
            loss = loss_buf.to_literal_sync().map_err(xe)?.to_vec::<f32>().map_err(xe)?[0];
            buffers = it.collect();
        }
        // `state` (and its keepalive literals) lives until here; every
        // buffer it uploaded has been consumed by execute_b above.
        drop(state);
        Ok(TrainState {
            buffers,
            step: next_step,
            last_loss: loss,
            host_keepalive: keepalive,
        })
    }

    /// Extract parameter bytes (f32 LE) in manifest order — what the
    /// checkpoint engines flush. Returns (name, bytes) pairs.
    pub fn export_params(&self, state: &TrainState) -> Result<Vec<(String, Vec<u8>)>> {
        let n = self.manifest.params.len();
        let mut out = Vec::with_capacity(2 * n);
        for (i, buf) in state.buffers.iter().enumerate() {
            let lit = buf.to_literal_sync().map_err(xe)?;
            let vals: Vec<f32> = lit.to_vec().map_err(xe)?;
            let name = if i < n {
                self.manifest.params[i].name.clone()
            } else {
                format!("momentum.{}", self.manifest.params[i - n].name)
            };
            // Bulk LE conversion (f32 slice → bytes). Little-endian
            // host, so this is a straight memcpy — measured 2.4x faster
            // than per-value collection (§Perf L3.2).
            let mut bytes = vec![0u8; vals.len() * 4];
            // SAFETY: f32 and [u8; 4] have identical size; LE layout.
            unsafe {
                std::ptr::copy_nonoverlapping(
                    vals.as_ptr() as *const u8,
                    bytes.as_mut_ptr(),
                    bytes.len(),
                );
            }
            out.push((name, bytes));
        }
        Ok(out)
    }

    /// Rebuild a TrainState from exported bytes (restore path).
    pub fn import_params(&self, blobs: &[(String, Vec<u8>)], step: u64) -> Result<TrainState> {
        let n = self.manifest.params.len();
        if blobs.len() != 2 * n {
            return Err(Error::Runtime(format!(
                "import: {} blobs != {} expected",
                blobs.len(),
                2 * n
            )));
        }
        let mut buffers = Vec::with_capacity(2 * n);
        let mut keepalive = Vec::with_capacity(2 * n);
        for (i, (_, bytes)) in blobs.iter().enumerate() {
            let spec = &self.manifest.params[i % n];
            if bytes.len() != spec.bytes() {
                return Err(Error::Runtime(format!(
                    "import: blob {i} has {} bytes, expected {}",
                    bytes.len(),
                    spec.bytes()
                )));
            }
            let mut vals = vec![0f32; bytes.len() / 4];
            // SAFETY: length checked above; LE host.
            unsafe {
                std::ptr::copy_nonoverlapping(
                    bytes.as_ptr(),
                    vals.as_mut_ptr() as *mut u8,
                    bytes.len(),
                );
            }
            let (buf, lit) = self.buffer_from_f32(&vals, &spec.shape)?;
            buffers.push(buf);
            keepalive.push(lit);
        }
        Ok(TrainState {
            buffers,
            step,
            last_loss: f32::NAN,
            host_keepalive: keepalive,
        })
    }

    /// Generate a synthetic token batch (deterministic, Zipf-ish mix of
    /// repeated n-grams so the LM has signal to learn).
    pub fn synthetic_batch(&self, rng: &mut Xoshiro256) -> (Vec<i32>, Vec<i32>) {
        let m = &self.manifest;
        let len = m.batch * m.seq_len;
        let mut tokens = Vec::with_capacity(len);
        // Repeating patterns + noise: predictable structure.
        for b in 0..m.batch {
            let period = 2 + (b % 6);
            let base = rng.gen_range(0, m.vocab as u64 / 2) as i32;
            for t in 0..m.seq_len {
                let structured = base + (t % period) as i32;
                let tok = if rng.next_f64() < 0.1 {
                    rng.gen_range(0, m.vocab as u64) as i32
                } else {
                    structured % m.vocab as i32
                };
                tokens.push(tok);
            }
        }
        // Next-token targets: shift left within each row.
        let mut targets = tokens.clone();
        for b in 0..m.batch {
            let row = &mut targets[b * m.seq_len..(b + 1) * m.seq_len];
            row.rotate_left(1);
        }
        (tokens, targets)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    // PJRT executions must not interleave across test threads (the
    // global client serializes buffer ops, but whole-test determinism
    // is easier to reason about under a gate).
    static PJRT_GATE: std::sync::Mutex<()> = std::sync::Mutex::new(());

    fn with_runtime(f: impl FnOnce(&ModelRuntime)) {
        let _gate = PJRT_GATE.lock().unwrap_or_else(|e| e.into_inner());
        let dir = artifacts_dir();
        if !dir.join("model_tiny.manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let rt = ModelRuntime::load(&dir, "tiny").unwrap();
        f(&rt);
    }

    #[test]
    fn init_and_step_decrease_loss() {
        with_runtime(|rt| {
        let mut state = rt.init_state().unwrap();
        assert_eq!(state.buffers.len(), 2 * rt.manifest.params.len());
        let mut rng = Xoshiro256::seeded(42);
        let (tok, tgt) = rt.synthetic_batch(&mut rng);
        let (tok, _tok_lit) = rt.token_buffer(&tok).unwrap();
        let (tgt, _tgt_lit) = rt.token_buffer(&tgt).unwrap();
        let mut losses = Vec::new();
        for _ in 0..6 {
            state = rt.train_step(state, &tok, &tgt).unwrap();
            losses.push(state.last_loss);
        }
        assert!(losses.iter().all(|l| l.is_finite()));
        assert!(
            losses.last().unwrap() < losses.first().unwrap(),
            "{losses:?}"
        );
        });
    }

    #[test]
    fn export_import_roundtrip_bitexact() {
        with_runtime(|rt| {
        let state = rt.init_state().unwrap();
        let blobs = rt.export_params(&state).unwrap();
        assert_eq!(blobs.len(), 2 * rt.manifest.params.len());
        let restored = rt.import_params(&blobs, 7).unwrap();
        assert_eq!(restored.step, 7);
        let blobs2 = rt.export_params(&restored).unwrap();
        for ((n1, b1), (n2, b2)) in blobs.iter().zip(&blobs2) {
            assert_eq!(n1, n2);
            assert_eq!(b1, b2, "round-trip bytes differ for {n1}");
        }
        });
    }

    #[test]
    fn synthetic_batch_in_vocab() {
        with_runtime(|rt| {
        let mut rng = Xoshiro256::seeded(1);
        let (tok, tgt) = rt.synthetic_batch(&mut rng);
        let m = &rt.manifest;
        assert_eq!(tok.len(), m.batch * m.seq_len);
        assert!(tok.iter().all(|&t| (0..m.vocab as i32).contains(&t)));
        assert!(tgt.iter().all(|&t| (0..m.vocab as i32).contains(&t)));
        });
    }
}
