//! Page-aligned host buffers for O_DIRECT and registered-buffer I/O.
//!
//! O_DIRECT requires the user buffer address and transfer length to be
//! aligned to the device logical block size; we align to 4096 which
//! satisfies every common device. These buffers are also what gets pinned
//! by `IORING_REGISTER_BUFFERS` for zero-copy fixed I/O, and they are the
//! unit managed by `ckpt::bufpool` (the preallocated-reuse strategy the
//! paper shows doubles DataStates-LLM restore throughput).

use std::alloc::{alloc_zeroed, dealloc, Layout};
use std::ops::{Deref, DerefMut};

use crate::util::align::{align_up, DIRECT_IO_ALIGN};

/// A heap buffer whose address and capacity are 4096-byte aligned.
pub struct AlignedBuf {
    ptr: *mut u8,
    len: usize,
    layout: Layout,
}

// SAFETY: AlignedBuf owns its allocation exclusively; the raw pointer is
// not aliased elsewhere, so transferring it across threads is sound.
unsafe impl Send for AlignedBuf {}
unsafe impl Sync for AlignedBuf {}

impl AlignedBuf {
    /// Allocate a zeroed buffer of `len` bytes rounded **up** to the
    /// direct-I/O alignment. Panics on zero length or allocation failure.
    pub fn zeroed(len: usize) -> Self {
        assert!(len > 0, "AlignedBuf of zero length");
        let cap = align_up(len as u64, DIRECT_IO_ALIGN) as usize;
        let layout = Layout::from_size_align(cap, DIRECT_IO_ALIGN as usize)
            .expect("bad layout");
        // SAFETY: layout has non-zero size.
        let ptr = unsafe { alloc_zeroed(layout) };
        assert!(!ptr.is_null(), "allocation of {cap} bytes failed");
        Self {
            ptr,
            len: cap,
            layout,
        }
    }

    /// Capacity in bytes (always a multiple of 4096).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        false // by construction len > 0
    }

    pub fn as_ptr(&self) -> *const u8 {
        self.ptr
    }

    pub fn as_mut_ptr(&mut self) -> *mut u8 {
        self.ptr
    }

    /// The buffer as an iovec for buffer registration.
    pub fn as_iovec(&self) -> libc::iovec {
        libc::iovec {
            iov_base: self.ptr as *mut libc::c_void,
            iov_len: self.len,
        }
    }

    /// Copy `src` into the buffer starting at `offset`.
    /// Panics if it does not fit.
    pub fn write_at(&mut self, offset: usize, src: &[u8]) {
        assert!(
            offset + src.len() <= self.len,
            "write_at out of bounds: {} + {} > {}",
            offset,
            src.len(),
            self.len
        );
        self[offset..offset + src.len()].copy_from_slice(src);
    }
}

impl Deref for AlignedBuf {
    type Target = [u8];
    fn deref(&self) -> &[u8] {
        // SAFETY: ptr/len describe our live allocation.
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }
}

impl DerefMut for AlignedBuf {
    fn deref_mut(&mut self) -> &mut [u8] {
        // SAFETY: ptr/len describe our live allocation; &mut self is unique.
        unsafe { std::slice::from_raw_parts_mut(self.ptr, self.len) }
    }
}

impl Drop for AlignedBuf {
    fn drop(&mut self) {
        // SAFETY: ptr/layout are exactly what alloc_zeroed returned.
        unsafe { dealloc(self.ptr, self.layout) };
    }
}

impl std::fmt::Debug for AlignedBuf {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "AlignedBuf {{ len: {}, ptr: {:p} }}", self.len, self.ptr)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::align::ptr_is_aligned;

    #[test]
    fn aligned_and_rounded() {
        let b = AlignedBuf::zeroed(100);
        assert_eq!(b.len(), 4096);
        assert!(ptr_is_aligned(b.as_ptr(), DIRECT_IO_ALIGN));
    }

    #[test]
    fn exact_multiple_not_grown() {
        let b = AlignedBuf::zeroed(8192);
        assert_eq!(b.len(), 8192);
    }

    #[test]
    fn zeroed_content() {
        let b = AlignedBuf::zeroed(4096);
        assert!(b.iter().all(|&x| x == 0));
    }

    #[test]
    fn write_and_read_back() {
        let mut b = AlignedBuf::zeroed(4096);
        b.write_at(10, b"hello");
        assert_eq!(&b[10..15], b"hello");
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn write_oob_panics() {
        let mut b = AlignedBuf::zeroed(4096);
        b.write_at(4094, b"xyz");
    }

    #[test]
    fn send_across_threads() {
        let mut b = AlignedBuf::zeroed(4096);
        b.write_at(0, b"abc");
        let handle = std::thread::spawn(move || b[0]);
        assert_eq!(handle.join().unwrap(), b'a');
    }
}
