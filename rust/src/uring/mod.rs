//! A from-scratch liburing port over raw `io_uring_*` syscalls.
//!
//! The paper studies liburing (the C userspace library for the Linux
//! `io_uring` interface). The offline crate set has no io-uring binding,
//! so this module reimplements the parts the checkpoint engines need,
//! directly against the kernel ABI:
//!
//! * [`sys`] — syscall numbers, `repr(C)` ABI structs, mmap offsets.
//! * [`ring`] — [`ring::IoUring`]: mmap'd submission/completion rings,
//!   SQE preparation (read/write/read_fixed/write_fixed/fsync), batched
//!   submit, completion reaping, buffer/file registration, plus the
//!   opt-in raw-speed features ([`ring::UringFeatures`]): sparse
//!   fixed-file tables, SQPOLL zero-syscall submission, and
//!   kernel-ordered (`IOSQE_IO_DRAIN`/`IOSQE_IO_LINK`) write→fsync
//!   chains — each with graceful per-feature fallback.
//! * [`buf`] — [`buf::AlignedBuf`]: page-aligned host buffers satisfying
//!   O_DIRECT's address/length alignment requirements; the unit of the
//!   preallocated buffer pools the paper recommends (Observation 3).
//!
//! Semantics mirrored from liburing: a single mmap for SQ+CQ when the
//! kernel advertises `IORING_FEAT_SINGLE_MMAP`, release/acquire ordering
//! on ring heads/tails, and the `sq_array` indirection table.

pub mod buf;
pub mod ring;
pub mod sys;

pub use buf::AlignedBuf;
pub use ring::{
    probe_features, Completion, FdSlot, IoUring, RingStats, SqeOpts, UringFeatures,
};
