//! Raw io_uring kernel ABI: syscall numbers, structs, constants.
//!
//! Layouts follow `<linux/io_uring.h>`; verified by the size/offset tests
//! at the bottom of this file (the kernel rejects mis-sized params with
//! EINVAL, so the smoke test in `ring` exercises these for real).

#![allow(non_camel_case_types)]
#![warn(missing_docs)]

use std::io;

/// `io_uring_setup(2)` syscall number (x86_64; same value on aarch64).
pub const SYS_IO_URING_SETUP: libc::c_long = 425;
/// `io_uring_enter(2)` syscall number (x86_64; same value on aarch64).
pub const SYS_IO_URING_ENTER: libc::c_long = 426;
/// `io_uring_register(2)` syscall number (x86_64; same value on aarch64).
pub const SYS_IO_URING_REGISTER: libc::c_long = 427;

/// mmap offset selecting the SQ ring region.
pub const IORING_OFF_SQ_RING: libc::off_t = 0;
/// mmap offset selecting the CQ ring region (pre-`SINGLE_MMAP` kernels).
pub const IORING_OFF_CQ_RING: libc::off_t = 0x800_0000;
/// mmap offset selecting the SQE array region.
pub const IORING_OFF_SQES: libc::off_t = 0x1000_0000;

/// `io_uring_setup` flag: kernel spawns an SQ polling thread that
/// consumes published SQEs without an `io_uring_enter` call. The thread
/// sleeps after `sq_thread_idle` ms of inactivity and must then be woken
/// with [`IORING_ENTER_SQ_WAKEUP`] (signalled via
/// [`IORING_SQ_NEED_WAKEUP`] in the SQ ring flags word).
pub const IORING_SETUP_SQPOLL: u32 = 1 << 1;

/// `io_uring_enter` flag: block until `min_complete` completions post.
pub const IORING_ENTER_GETEVENTS: libc::c_uint = 1;
/// `io_uring_enter` flag: wake an idle SQPOLL kernel thread.
pub const IORING_ENTER_SQ_WAKEUP: libc::c_uint = 1 << 1;

/// SQ ring `flags` bit: the SQPOLL thread went idle; the submitter must
/// call `io_uring_enter` with [`IORING_ENTER_SQ_WAKEUP`] to resume it.
pub const IORING_SQ_NEED_WAKEUP: u32 = 1 << 0;

/// Feature bit: SQ and CQ rings share one mmap region.
pub const IORING_FEAT_SINGLE_MMAP: u32 = 1 << 0;
/// Feature bit (kernel >= 5.11): SQPOLL no longer requires every op to
/// use registered (fixed) files. On kernels without it, SQPOLL rings
/// silently fail raw-fd ops with EBADF, so the ring layer only keeps
/// SQPOLL active when this bit is granted or fixed files are in use.
pub const IORING_FEAT_SQPOLL_NONFIXED: u32 = 1 << 7;

/// `io_uring_register` opcode: register fixed buffers.
pub const IORING_REGISTER_BUFFERS: libc::c_uint = 0;
/// `io_uring_register` opcode: unregister fixed buffers.
pub const IORING_UNREGISTER_BUFFERS: libc::c_uint = 1;
/// `io_uring_register` opcode: register a fixed file table.
pub const IORING_REGISTER_FILES: libc::c_uint = 2;
/// `io_uring_register` opcode: unregister the fixed file table.
pub const IORING_UNREGISTER_FILES: libc::c_uint = 3;
/// `io_uring_register` opcode: update slots of a registered file table
/// in place (arg is an [`io_uring_files_update`]); fd -1 clears a slot.
pub const IORING_REGISTER_FILES_UPDATE: libc::c_uint = 6;

/// SQE flag: `fd` is an index into the registered file table, not a
/// raw descriptor.
pub const IOSQE_FIXED_FILE: u8 = 1 << 0;
/// SQE flag: issue this op only after all prior SQEs complete (a full
/// ordering barrier — the write→fsync chain the checkpoint path uses).
pub const IOSQE_IO_DRAIN: u8 = 1 << 1;
/// SQE flag: the next SQE starts only after this one completes
/// (pairwise link, weaker than [`IOSQE_IO_DRAIN`]).
pub const IOSQE_IO_LINK: u8 = 1 << 2;

/// SQE opcode: no-op (submission-overhead microbenchmarks).
pub const IORING_OP_NOP: u8 = 0;
/// SQE opcode: vectored read.
pub const IORING_OP_READV: u8 = 1;
/// SQE opcode: vectored write.
pub const IORING_OP_WRITEV: u8 = 2;
/// SQE opcode: fsync.
pub const IORING_OP_FSYNC: u8 = 3;
/// SQE opcode: read into a registered buffer.
pub const IORING_OP_READ_FIXED: u8 = 4;
/// SQE opcode: write from a registered buffer.
pub const IORING_OP_WRITE_FIXED: u8 = 5;
/// SQE opcode: positional read.
pub const IORING_OP_READ: u8 = 22;
/// SQE opcode: positional write.
pub const IORING_OP_WRITE: u8 = 23;

/// Offsets of SQ ring fields within the SQ ring mmap.
#[repr(C)]
#[derive(Debug, Default, Clone, Copy)]
pub struct io_sqring_offsets {
    /// Offset of the kernel-consumed head index.
    pub head: u32,
    /// Offset of the userspace-produced tail index.
    pub tail: u32,
    /// Offset of the ring mask word (`ring_entries - 1`).
    pub ring_mask: u32,
    /// Offset of the ring size word.
    pub ring_entries: u32,
    /// Offset of the SQ flags word ([`IORING_SQ_NEED_WAKEUP`] lives here).
    pub flags: u32,
    /// Offset of the dropped-SQE counter.
    pub dropped: u32,
    /// Offset of the SQE index indirection array.
    pub array: u32,
    /// Reserved.
    pub resv1: u32,
    /// Reserved / ring address (NO_MMAP kernels).
    pub user_addr: u64,
}

/// Offsets of CQ ring fields within the CQ ring mmap.
#[repr(C)]
#[derive(Debug, Default, Clone, Copy)]
pub struct io_cqring_offsets {
    /// Offset of the userspace-consumed head index.
    pub head: u32,
    /// Offset of the kernel-produced tail index.
    pub tail: u32,
    /// Offset of the ring mask word.
    pub ring_mask: u32,
    /// Offset of the ring size word.
    pub ring_entries: u32,
    /// Offset of the overflow counter.
    pub overflow: u32,
    /// Offset of the CQE array.
    pub cqes: u32,
    /// Offset of the CQ flags word.
    pub flags: u32,
    /// Reserved.
    pub resv1: u32,
    /// Reserved / ring address (NO_MMAP kernels).
    pub user_addr: u64,
}

/// Setup parameters / results for `io_uring_setup`.
#[repr(C)]
#[derive(Debug, Default, Clone, Copy)]
pub struct io_uring_params {
    /// SQ size granted by the kernel (out).
    pub sq_entries: u32,
    /// CQ size granted by the kernel (out).
    pub cq_entries: u32,
    /// Setup flags, e.g. [`IORING_SETUP_SQPOLL`] (in).
    pub flags: u32,
    /// CPU to pin the SQPOLL thread to (in, with SETUP_SQ_AFF).
    pub sq_thread_cpu: u32,
    /// SQPOLL thread idle timeout in milliseconds (in).
    pub sq_thread_idle: u32,
    /// Feature bits granted by the kernel (out).
    pub features: u32,
    /// Workqueue fd to share (in, with SETUP_ATTACH_WQ).
    pub wq_fd: u32,
    /// Reserved.
    pub resv: [u32; 3],
    /// SQ ring field offsets (out).
    pub sq_off: io_sqring_offsets,
    /// CQ ring field offsets (out).
    pub cq_off: io_cqring_offsets,
}

/// Submission queue entry (64 bytes).
#[repr(C)]
#[derive(Debug, Default, Clone, Copy)]
pub struct io_uring_sqe {
    /// Operation (`IORING_OP_*`).
    pub opcode: u8,
    /// Per-SQE modifier flags (`IOSQE_*`).
    pub flags: u8,
    /// I/O priority (unused here).
    pub ioprio: u16,
    /// Raw fd, or fixed-file table index when [`IOSQE_FIXED_FILE`] is set.
    pub fd: i32,
    /// File offset.
    pub off: u64,
    /// Buffer address.
    pub addr: u64,
    /// Transfer length in bytes.
    pub len: u32,
    /// Union in the kernel header (rw_flags / fsync_flags / ...).
    pub op_flags: u32,
    /// Caller cookie echoed back in the CQE.
    pub user_data: u64,
    /// Union: buf_index for *_FIXED ops.
    pub buf_index: u16,
    /// Registered personality (unused here).
    pub personality: u16,
    /// Union: splice fd / file index (unused here).
    pub splice_fd_in: i32,
    /// Padding / extended fields.
    pub pad2: [u64; 2],
}

/// Completion queue entry (16 bytes).
#[repr(C)]
#[derive(Debug, Default, Clone, Copy)]
pub struct io_uring_cqe {
    /// The cookie from the originating SQE.
    pub user_data: u64,
    /// Bytes transferred, or `-errno`.
    pub res: i32,
    /// CQE flags (buffer id for provided buffers; unused here).
    pub flags: u32,
}

/// Argument block for [`IORING_REGISTER_FILES_UPDATE`]: replaces
/// `fds.len()` slots of the registered file table starting at `offset`.
#[repr(C)]
#[derive(Debug, Default, Clone, Copy)]
pub struct io_uring_files_update {
    /// First table slot to replace.
    pub offset: u32,
    /// Reserved, must be zero.
    pub resv: u32,
    /// Userspace pointer to an `i32` fd array (-1 clears a slot).
    pub fds: u64,
}

/// `io_uring_setup(2)`.
pub fn io_uring_setup(entries: u32, params: &mut io_uring_params) -> io::Result<i32> {
    // SAFETY: params is a valid, properly-sized io_uring_params.
    let ret = unsafe {
        libc::syscall(
            SYS_IO_URING_SETUP,
            entries as libc::c_uint,
            params as *mut io_uring_params,
        )
    };
    if ret < 0 {
        Err(io::Error::last_os_error())
    } else {
        Ok(ret as i32)
    }
}

/// `io_uring_enter(2)`.
pub fn io_uring_enter(
    fd: i32,
    to_submit: u32,
    min_complete: u32,
    flags: libc::c_uint,
) -> io::Result<u32> {
    // SAFETY: plain syscall with integer args; sigset omitted (NULL).
    let ret = unsafe {
        libc::syscall(
            SYS_IO_URING_ENTER,
            fd,
            to_submit as libc::c_uint,
            min_complete as libc::c_uint,
            flags,
            std::ptr::null::<libc::sigset_t>(),
            0usize,
        )
    };
    if ret < 0 {
        Err(io::Error::last_os_error())
    } else {
        Ok(ret as u32)
    }
}

/// `io_uring_register(2)`.
pub fn io_uring_register(
    fd: i32,
    opcode: libc::c_uint,
    arg: *const libc::c_void,
    nr_args: u32,
) -> io::Result<()> {
    // SAFETY: arg/nr_args validity is the caller's contract per opcode.
    let ret = unsafe { libc::syscall(SYS_IO_URING_REGISTER, fd, opcode, arg, nr_args as libc::c_uint) };
    if ret < 0 {
        Err(io::Error::last_os_error())
    } else {
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::mem::size_of;

    #[test]
    fn abi_struct_sizes_match_kernel() {
        assert_eq!(size_of::<io_uring_sqe>(), 64);
        assert_eq!(size_of::<io_uring_cqe>(), 16);
        assert_eq!(size_of::<io_sqring_offsets>(), 40);
        assert_eq!(size_of::<io_cqring_offsets>(), 40);
        assert_eq!(size_of::<io_uring_params>(), 120);
        assert_eq!(size_of::<io_uring_files_update>(), 16);
    }

    #[test]
    fn setup_syscall_accepted_by_kernel() {
        // The strongest ABI check: the kernel validates the params size.
        let mut p = io_uring_params::default();
        let fd = match io_uring_setup(4, &mut p) {
            Ok(fd) => fd,
            Err(e) => {
                eprintln!("skipping: io_uring unavailable on this kernel ({e})");
                return;
            }
        };
        assert!(fd >= 0);
        assert!(p.sq_entries >= 4);
        assert!(p.cq_entries >= p.sq_entries);
        // SAFETY: fd came from io_uring_setup.
        unsafe { libc::close(fd) };
    }
}
