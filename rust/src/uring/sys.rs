//! Raw io_uring kernel ABI: syscall numbers, structs, constants.
//!
//! Layouts follow `<linux/io_uring.h>`; verified by the size/offset tests
//! at the bottom of this file (the kernel rejects mis-sized params with
//! EINVAL, so the smoke test in `ring` exercises these for real).

#![allow(non_camel_case_types)]

use std::io;

// x86_64 syscall numbers (same values on aarch64 for these three).
pub const SYS_IO_URING_SETUP: libc::c_long = 425;
pub const SYS_IO_URING_ENTER: libc::c_long = 426;
pub const SYS_IO_URING_REGISTER: libc::c_long = 427;

// mmap offsets selecting which ring region to map.
pub const IORING_OFF_SQ_RING: libc::off_t = 0;
pub const IORING_OFF_CQ_RING: libc::off_t = 0x800_0000;
pub const IORING_OFF_SQES: libc::off_t = 0x1000_0000;

// io_uring_enter flags.
pub const IORING_ENTER_GETEVENTS: libc::c_uint = 1;

// Feature bits reported in io_uring_params.features.
pub const IORING_FEAT_SINGLE_MMAP: u32 = 1 << 0;

// Register opcodes.
pub const IORING_REGISTER_BUFFERS: libc::c_uint = 0;
pub const IORING_UNREGISTER_BUFFERS: libc::c_uint = 1;
pub const IORING_REGISTER_FILES: libc::c_uint = 2;
pub const IORING_UNREGISTER_FILES: libc::c_uint = 3;

// SQE opcodes (subset used by the checkpoint engines).
pub const IORING_OP_NOP: u8 = 0;
pub const IORING_OP_READV: u8 = 1;
pub const IORING_OP_WRITEV: u8 = 2;
pub const IORING_OP_FSYNC: u8 = 3;
pub const IORING_OP_READ_FIXED: u8 = 4;
pub const IORING_OP_WRITE_FIXED: u8 = 5;
pub const IORING_OP_READ: u8 = 22;
pub const IORING_OP_WRITE: u8 = 23;

/// Offsets of SQ ring fields within the SQ ring mmap.
#[repr(C)]
#[derive(Debug, Default, Clone, Copy)]
pub struct io_sqring_offsets {
    pub head: u32,
    pub tail: u32,
    pub ring_mask: u32,
    pub ring_entries: u32,
    pub flags: u32,
    pub dropped: u32,
    pub array: u32,
    pub resv1: u32,
    pub user_addr: u64,
}

/// Offsets of CQ ring fields within the CQ ring mmap.
#[repr(C)]
#[derive(Debug, Default, Clone, Copy)]
pub struct io_cqring_offsets {
    pub head: u32,
    pub tail: u32,
    pub ring_mask: u32,
    pub ring_entries: u32,
    pub overflow: u32,
    pub cqes: u32,
    pub flags: u32,
    pub resv1: u32,
    pub user_addr: u64,
}

/// Setup parameters / results for `io_uring_setup`.
#[repr(C)]
#[derive(Debug, Default, Clone, Copy)]
pub struct io_uring_params {
    pub sq_entries: u32,
    pub cq_entries: u32,
    pub flags: u32,
    pub sq_thread_cpu: u32,
    pub sq_thread_idle: u32,
    pub features: u32,
    pub wq_fd: u32,
    pub resv: [u32; 3],
    pub sq_off: io_sqring_offsets,
    pub cq_off: io_cqring_offsets,
}

/// Submission queue entry (64 bytes).
#[repr(C)]
#[derive(Debug, Default, Clone, Copy)]
pub struct io_uring_sqe {
    pub opcode: u8,
    pub flags: u8,
    pub ioprio: u16,
    pub fd: i32,
    pub off: u64,
    pub addr: u64,
    pub len: u32,
    /// Union in the kernel header (rw_flags / fsync_flags / ...).
    pub op_flags: u32,
    pub user_data: u64,
    /// Union: buf_index for *_FIXED ops.
    pub buf_index: u16,
    pub personality: u16,
    pub splice_fd_in: i32,
    pub pad2: [u64; 2],
}

/// Completion queue entry (16 bytes).
#[repr(C)]
#[derive(Debug, Default, Clone, Copy)]
pub struct io_uring_cqe {
    pub user_data: u64,
    pub res: i32,
    pub flags: u32,
}

/// `io_uring_setup(2)`.
pub fn io_uring_setup(entries: u32, params: &mut io_uring_params) -> io::Result<i32> {
    // SAFETY: params is a valid, properly-sized io_uring_params.
    let ret = unsafe {
        libc::syscall(
            SYS_IO_URING_SETUP,
            entries as libc::c_uint,
            params as *mut io_uring_params,
        )
    };
    if ret < 0 {
        Err(io::Error::last_os_error())
    } else {
        Ok(ret as i32)
    }
}

/// `io_uring_enter(2)`.
pub fn io_uring_enter(
    fd: i32,
    to_submit: u32,
    min_complete: u32,
    flags: libc::c_uint,
) -> io::Result<u32> {
    // SAFETY: plain syscall with integer args; sigset omitted (NULL).
    let ret = unsafe {
        libc::syscall(
            SYS_IO_URING_ENTER,
            fd,
            to_submit as libc::c_uint,
            min_complete as libc::c_uint,
            flags,
            std::ptr::null::<libc::sigset_t>(),
            0usize,
        )
    };
    if ret < 0 {
        Err(io::Error::last_os_error())
    } else {
        Ok(ret as u32)
    }
}

/// `io_uring_register(2)`.
pub fn io_uring_register(
    fd: i32,
    opcode: libc::c_uint,
    arg: *const libc::c_void,
    nr_args: u32,
) -> io::Result<()> {
    // SAFETY: arg/nr_args validity is the caller's contract per opcode.
    let ret = unsafe { libc::syscall(SYS_IO_URING_REGISTER, fd, opcode, arg, nr_args as libc::c_uint) };
    if ret < 0 {
        Err(io::Error::last_os_error())
    } else {
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::mem::size_of;

    #[test]
    fn abi_struct_sizes_match_kernel() {
        assert_eq!(size_of::<io_uring_sqe>(), 64);
        assert_eq!(size_of::<io_uring_cqe>(), 16);
        assert_eq!(size_of::<io_sqring_offsets>(), 40);
        assert_eq!(size_of::<io_cqring_offsets>(), 40);
        assert_eq!(size_of::<io_uring_params>(), 120);
    }

    #[test]
    fn setup_syscall_accepted_by_kernel() {
        // The strongest ABI check: the kernel validates the params size.
        let mut p = io_uring_params::default();
        let fd = match io_uring_setup(4, &mut p) {
            Ok(fd) => fd,
            Err(e) => {
                eprintln!("skipping: io_uring unavailable on this kernel ({e})");
                return;
            }
        };
        assert!(fd >= 0);
        assert!(p.sq_entries >= 4);
        assert!(p.cq_entries >= p.sq_entries);
        // SAFETY: fd came from io_uring_setup.
        unsafe { libc::close(fd) };
    }
}
