//! The io_uring ring: setup, SQE preparation, submission, completion.
//!
//! This mirrors liburing's `io_uring_queue_init` / `io_uring_get_sqe` /
//! `io_uring_submit(_and_wait)` / `io_uring_{peek,wait}_cqe` API surface,
//! implemented directly over the kernel ABI in [`super::sys`].
//!
//! Memory-ordering protocol (same as liburing):
//! * SQ: the kernel consumes `head` (we load-acquire), we produce `tail`
//!   (store-release after filling SQEs and the index array).
//! * CQ: the kernel produces `tail` (we load-acquire), we consume `head`
//!   (store-release after reading the CQE).

use std::io;
use std::ptr::NonNull;
use std::sync::atomic::{AtomicU32, Ordering};

use crate::error::{Error, Result};

use super::sys::{self, io_uring_cqe, io_uring_params, io_uring_sqe};

/// A reaped completion.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Completion {
    /// The `user_data` attached at prep time (an operation id).
    pub user_data: u64,
    /// Bytes transferred on success, `-errno` on failure.
    pub result: i32,
    pub flags: u32,
}

impl Completion {
    /// Bytes transferred, or the operation's error.
    pub fn bytes(&self) -> io::Result<u32> {
        if self.result < 0 {
            Err(io::Error::from_raw_os_error(-self.result))
        } else {
            Ok(self.result as u32)
        }
    }
}

struct Mmap {
    ptr: NonNull<u8>,
    len: usize,
}

impl Mmap {
    fn map(fd: i32, len: usize, offset: libc::off_t) -> io::Result<Self> {
        // SAFETY: standard mmap of an io_uring region; kernel validates
        // len/offset against the ring geometry.
        let ptr = unsafe {
            libc::mmap(
                std::ptr::null_mut(),
                len,
                libc::PROT_READ | libc::PROT_WRITE,
                libc::MAP_SHARED | libc::MAP_POPULATE,
                fd,
                offset,
            )
        };
        if ptr == libc::MAP_FAILED {
            return Err(io::Error::last_os_error());
        }
        Ok(Self {
            ptr: NonNull::new(ptr as *mut u8).expect("mmap returned null"),
            len,
        })
    }

    /// Pointer to `offset` bytes into the mapping.
    fn at(&self, offset: u32) -> *mut u8 {
        debug_assert!((offset as usize) < self.len);
        // SAFETY: offset < len per ring geometry.
        unsafe { self.ptr.as_ptr().add(offset as usize) }
    }
}

impl Drop for Mmap {
    fn drop(&mut self) {
        // SAFETY: exactly the region we mapped.
        unsafe { libc::munmap(self.ptr.as_ptr() as *mut libc::c_void, self.len) };
    }
}

/// Submission-queue view into the mapped ring.
struct Sq {
    /// Keeps the SQ ring mapping alive (fields below point into it).
    _ring: Mmap,
    head: *const AtomicU32,
    tail: *const AtomicU32,
    ring_mask: u32,
    ring_entries: u32,
    array: *mut u32,
    sqes: Mmap,
    /// Our local (not yet published) tail.
    sqe_tail: u32,
    /// Local cache of the published tail (for space accounting).
    sqe_head: u32,
}

/// Completion-queue view into the mapped ring.
struct Cq {
    /// Present only when the kernel lacks IORING_FEAT_SINGLE_MMAP (we keep
    /// the separate mapping alive here).
    _ring: Option<Mmap>,
    head: *const AtomicU32,
    tail: *const AtomicU32,
    ring_mask: u32,
    cqes: *const io_uring_cqe,
}

/// An io_uring instance.
///
/// Not `Sync`: one ring per thread, the same discipline liburing
/// recommends and the checkpoint engines follow (ring-per-rank).
pub struct IoUring {
    fd: i32,
    sq: Sq,
    cq: Cq,
    params: io_uring_params,
    registered_buffers: bool,
    registered_files: bool,
    stats: RingStats,
}

/// Submission-batching tallies for one ring: how many `io_uring_enter`
/// submission calls were made and how many SQEs they carried. The ratio
/// is the batching efficiency the aggregation strategies trade on (a
/// plain per-thread counter — the ring is not `Sync`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RingStats {
    /// `io_uring_enter` calls that submitted at least one SQE.
    pub submit_calls: u64,
    /// SQEs those calls published to the kernel.
    pub sqes_submitted: u64,
}

// SAFETY: all raw pointers reference the ring mmaps owned by this value;
// moving the struct between threads is fine (no thread affinity), it is
// just not usable concurrently (not Sync).
unsafe impl Send for IoUring {}

impl IoUring {
    /// Does this kernel support io_uring? Probed once per process.
    /// Sandboxed runtimes (gVisor, seccomp-filtered containers) and
    /// pre-5.1 kernels return ENOSYS/EPERM from `io_uring_setup`; the
    /// real executor uses this to degrade gracefully to POSIX.
    pub fn is_supported() -> bool {
        static SUPPORTED: once_cell::sync::Lazy<bool> =
            once_cell::sync::Lazy::new(|| IoUring::new(2).is_ok());
        *SUPPORTED
    }

    /// Create a ring with at least `entries` SQ slots (rounded up to a
    /// power of two by the kernel).
    pub fn new(entries: u32) -> Result<Self> {
        let mut params = io_uring_params::default();
        let fd = sys::io_uring_setup(entries, &mut params).map_err(|e| Error::Uring {
            op: "io_uring_setup",
            source: e,
        })?;
        match Self::map_rings(fd, params) {
            Ok(ring) => Ok(ring),
            Err(e) => {
                // SAFETY: fd from io_uring_setup, not yet wrapped.
                unsafe { libc::close(fd) };
                Err(e)
            }
        }
    }

    fn map_rings(fd: i32, params: io_uring_params) -> Result<Self> {
        let sq_ring_len =
            params.sq_off.array as usize + params.sq_entries as usize * std::mem::size_of::<u32>();
        let cq_ring_len = params.cq_off.cqes as usize
            + params.cq_entries as usize * std::mem::size_of::<io_uring_cqe>();
        let single = params.features & sys::IORING_FEAT_SINGLE_MMAP != 0;
        let map_err = |op: &'static str| move |e: io::Error| Error::Uring { op, source: e };

        let sq_map_len = if single {
            sq_ring_len.max(cq_ring_len)
        } else {
            sq_ring_len
        };
        let sq_ring = Mmap::map(fd, sq_map_len, sys::IORING_OFF_SQ_RING)
            .map_err(map_err("mmap sq_ring"))?;
        let (cq_ring, cq_base): (Option<Mmap>, *mut u8) = if single {
            (None, sq_ring.ptr.as_ptr())
        } else {
            let m = Mmap::map(fd, cq_ring_len, sys::IORING_OFF_CQ_RING)
                .map_err(map_err("mmap cq_ring"))?;
            let p = m.ptr.as_ptr();
            (Some(m), p)
        };
        let sqes = Mmap::map(
            fd,
            params.sq_entries as usize * std::mem::size_of::<io_uring_sqe>(),
            sys::IORING_OFF_SQES,
        )
        .map_err(map_err("mmap sqes"))?;

        // SAFETY: all offsets come from the kernel's ring geometry.
        let sq = unsafe {
            Sq {
                head: sq_ring.at(params.sq_off.head) as *const AtomicU32,
                tail: sq_ring.at(params.sq_off.tail) as *const AtomicU32,
                ring_mask: *(sq_ring.at(params.sq_off.ring_mask) as *const u32),
                ring_entries: *(sq_ring.at(params.sq_off.ring_entries) as *const u32),
                array: sq_ring.at(params.sq_off.array) as *mut u32,
                sqe_tail: (*(sq_ring.at(params.sq_off.tail) as *const AtomicU32))
                    .load(Ordering::Relaxed),
                sqe_head: (*(sq_ring.at(params.sq_off.head) as *const AtomicU32))
                    .load(Ordering::Relaxed),
                _ring: sq_ring,
                sqes,
            }
        };
        let cq = unsafe {
            Cq {
                head: cq_base.add(params.cq_off.head as usize) as *const AtomicU32,
                tail: cq_base.add(params.cq_off.tail as usize) as *const AtomicU32,
                ring_mask: *(cq_base.add(params.cq_off.ring_mask as usize) as *const u32),
                cqes: cq_base.add(params.cq_off.cqes as usize) as *const io_uring_cqe,
                _ring: cq_ring,
            }
        };
        Ok(Self {
            fd,
            sq,
            cq,
            params,
            registered_buffers: false,
            registered_files: false,
            stats: RingStats::default(),
        })
    }

    /// Submission-batching tallies accumulated over the ring's lifetime.
    pub fn stats(&self) -> RingStats {
        self.stats
    }

    /// SQ capacity (entries).
    pub fn sq_entries(&self) -> u32 {
        self.sq.ring_entries
    }

    /// Unsubmitted + in-kernel slots currently free in the SQ.
    pub fn sq_space_left(&self) -> u32 {
        // SAFETY: head points into the live SQ ring mmap.
        let head = unsafe { (*self.sq.head).load(Ordering::Acquire) };
        self.sq.ring_entries - self.sq.sqe_tail.wrapping_sub(head)
    }

    /// Number of prepared-but-unsubmitted SQEs.
    pub fn sq_pending(&self) -> u32 {
        self.sq.sqe_tail.wrapping_sub(self.sq.sqe_head)
    }

    fn next_sqe(&mut self) -> Result<&mut io_uring_sqe> {
        if self.sq_space_left() == 0 {
            return Err(Error::Uring {
                op: "get_sqe",
                source: io::Error::new(io::ErrorKind::WouldBlock, "submission queue full"),
            });
        }
        let idx = (self.sq.sqe_tail & self.sq.ring_mask) as usize;
        self.sq.sqe_tail = self.sq.sqe_tail.wrapping_add(1);
        // SAFETY: idx < ring_entries; sqes mmap holds ring_entries SQEs.
        let sqe = unsafe {
            &mut *(self.sq.sqes.ptr.as_ptr() as *mut io_uring_sqe).add(idx)
        };
        *sqe = io_uring_sqe::default();
        Ok(sqe)
    }

    /// Queue a NOP (used by tests and the microbenchmark to measure pure
    /// submission overhead).
    pub fn prep_nop(&mut self, user_data: u64) -> Result<()> {
        let sqe = self.next_sqe()?;
        sqe.opcode = sys::IORING_OP_NOP;
        sqe.user_data = user_data;
        Ok(())
    }

    /// Queue a positional write of `len` bytes from `buf` at file `offset`.
    ///
    /// # Safety contract
    /// `buf` must stay alive and unmoved until the completion for
    /// `user_data` is reaped (enforced by the owning backend).
    pub fn prep_write(
        &mut self,
        fd: i32,
        buf: *const u8,
        len: u32,
        offset: u64,
        user_data: u64,
    ) -> Result<()> {
        let sqe = self.next_sqe()?;
        sqe.opcode = sys::IORING_OP_WRITE;
        sqe.fd = fd;
        sqe.addr = buf as u64;
        sqe.len = len;
        sqe.off = offset;
        sqe.user_data = user_data;
        Ok(())
    }

    /// Queue a positional read into `buf`.
    pub fn prep_read(
        &mut self,
        fd: i32,
        buf: *mut u8,
        len: u32,
        offset: u64,
        user_data: u64,
    ) -> Result<()> {
        let sqe = self.next_sqe()?;
        sqe.opcode = sys::IORING_OP_READ;
        sqe.fd = fd;
        sqe.addr = buf as u64;
        sqe.len = len;
        sqe.off = offset;
        sqe.user_data = user_data;
        Ok(())
    }

    /// Positional write from a registered buffer (`IORING_OP_WRITE_FIXED`).
    pub fn prep_write_fixed(
        &mut self,
        fd: i32,
        buf: *const u8,
        len: u32,
        offset: u64,
        buf_index: u16,
        user_data: u64,
    ) -> Result<()> {
        if !self.registered_buffers {
            return Err(Error::msg("write_fixed without registered buffers"));
        }
        let sqe = self.next_sqe()?;
        sqe.opcode = sys::IORING_OP_WRITE_FIXED;
        sqe.fd = fd;
        sqe.addr = buf as u64;
        sqe.len = len;
        sqe.off = offset;
        sqe.buf_index = buf_index;
        sqe.user_data = user_data;
        Ok(())
    }

    /// Positional read into a registered buffer (`IORING_OP_READ_FIXED`).
    pub fn prep_read_fixed(
        &mut self,
        fd: i32,
        buf: *mut u8,
        len: u32,
        offset: u64,
        buf_index: u16,
        user_data: u64,
    ) -> Result<()> {
        if !self.registered_buffers {
            return Err(Error::msg("read_fixed without registered buffers"));
        }
        let sqe = self.next_sqe()?;
        sqe.opcode = sys::IORING_OP_READ_FIXED;
        sqe.fd = fd;
        sqe.addr = buf as u64;
        sqe.len = len;
        sqe.off = offset;
        sqe.buf_index = buf_index;
        sqe.user_data = user_data;
        Ok(())
    }

    /// Queue an fsync.
    pub fn prep_fsync(&mut self, fd: i32, user_data: u64) -> Result<()> {
        let sqe = self.next_sqe()?;
        sqe.opcode = sys::IORING_OP_FSYNC;
        sqe.fd = fd;
        sqe.user_data = user_data;
        Ok(())
    }

    /// Publish prepared SQEs to the kernel-visible tail. Returns how many
    /// were published.
    fn flush_sq(&mut self) -> u32 {
        let to_submit = self.sq.sqe_tail.wrapping_sub(self.sq.sqe_head);
        if to_submit == 0 {
            return 0;
        }
        let mask = self.sq.ring_mask;
        let mut tail = self.sq.sqe_head;
        for _ in 0..to_submit {
            let idx = tail & mask;
            // SAFETY: array has ring_entries u32 slots; idx is masked.
            unsafe { *self.sq.array.add(idx as usize) = idx };
            tail = tail.wrapping_add(1);
        }
        debug_assert_eq!(tail, self.sq.sqe_tail);
        // SAFETY: tail points into the live SQ ring mmap. Release makes
        // the SQE writes visible to the kernel before the new tail.
        unsafe { (*self.sq.tail).store(tail, Ordering::Release) };
        self.sq.sqe_head = tail;
        to_submit
    }

    /// Submit all prepared SQEs. Returns the number the kernel consumed.
    pub fn submit(&mut self) -> Result<u32> {
        self.submit_and_wait(0)
    }

    /// Submit and block until at least `wait_for` completions are posted.
    pub fn submit_and_wait(&mut self, wait_for: u32) -> Result<u32> {
        let to_submit = self.flush_sq();
        if to_submit == 0 && wait_for == 0 {
            return Ok(0);
        }
        let flags = if wait_for > 0 {
            sys::IORING_ENTER_GETEVENTS
        } else {
            0
        };
        let submitted =
            sys::io_uring_enter(self.fd, to_submit, wait_for, flags).map_err(|e| Error::Uring {
                op: "io_uring_enter",
                source: e,
            })?;
        if to_submit > 0 {
            self.stats.submit_calls += 1;
            self.stats.sqes_submitted += u64::from(to_submit);
        }
        Ok(submitted)
    }

    /// Reap one completion if available, without blocking.
    pub fn peek_cqe(&mut self) -> Option<Completion> {
        // SAFETY: head/tail point into the live CQ ring mmap.
        let head = unsafe { (*self.cq.head).load(Ordering::Relaxed) };
        let tail = unsafe { (*self.cq.tail).load(Ordering::Acquire) };
        if head == tail {
            return None;
        }
        let idx = (head & self.cq.ring_mask) as usize;
        // SAFETY: idx masked into the CQE array.
        let cqe = unsafe { *self.cq.cqes.add(idx) };
        // SAFETY: publishing consumption back to the kernel.
        unsafe { (*self.cq.head).store(head.wrapping_add(1), Ordering::Release) };
        Some(Completion {
            user_data: cqe.user_data,
            result: cqe.res,
            flags: cqe.flags,
        })
    }

    /// Block until a completion is available and return it.
    pub fn wait_cqe(&mut self) -> Result<Completion> {
        loop {
            if let Some(c) = self.peek_cqe() {
                return Ok(c);
            }
            sys::io_uring_enter(self.fd, 0, 1, sys::IORING_ENTER_GETEVENTS).map_err(|e| {
                Error::Uring {
                    op: "io_uring_enter(wait)",
                    source: e,
                }
            })?;
        }
    }

    /// Reap up to `max` immediately-available completions.
    pub fn reap_available(&mut self, max: usize) -> Vec<Completion> {
        let mut out = Vec::new();
        while out.len() < max {
            match self.peek_cqe() {
                Some(c) => out.push(c),
                None => break,
            }
        }
        out
    }

    /// Register fixed buffers for zero-copy `*_FIXED` ops.
    pub fn register_buffers(&mut self, iovecs: &[libc::iovec]) -> Result<()> {
        sys::io_uring_register(
            self.fd,
            sys::IORING_REGISTER_BUFFERS,
            iovecs.as_ptr() as *const libc::c_void,
            iovecs.len() as u32,
        )
        .map_err(|e| Error::Uring {
            op: "register_buffers",
            source: e,
        })?;
        self.registered_buffers = true;
        Ok(())
    }

    pub fn unregister_buffers(&mut self) -> Result<()> {
        sys::io_uring_register(self.fd, sys::IORING_UNREGISTER_BUFFERS, std::ptr::null(), 0)
            .map_err(|e| Error::Uring {
                op: "unregister_buffers",
                source: e,
            })?;
        self.registered_buffers = false;
        Ok(())
    }

    /// Register a fixed file set.
    pub fn register_files(&mut self, fds: &[i32]) -> Result<()> {
        sys::io_uring_register(
            self.fd,
            sys::IORING_REGISTER_FILES,
            fds.as_ptr() as *const libc::c_void,
            fds.len() as u32,
        )
        .map_err(|e| Error::Uring {
            op: "register_files",
            source: e,
        })?;
        self.registered_files = true;
        Ok(())
    }

    pub fn has_registered_files(&self) -> bool {
        self.registered_files
    }

    /// Kernel-reported features bitmask.
    pub fn features(&self) -> u32 {
        self.params.features
    }
}

impl Drop for IoUring {
    fn drop(&mut self) {
        // SAFETY: fd owned by this ring.
        unsafe { libc::close(self.fd) };
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::uring::buf::AlignedBuf;
    use std::fs::{File, OpenOptions};
    use std::io::Read;
    use std::os::unix::io::AsRawFd;

    fn tmpfile(name: &str) -> (std::path::PathBuf, File) {
        let path = std::env::temp_dir().join(format!("ckptio-ring-{name}-{}", std::process::id()));
        let f = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(&path)
            .unwrap();
        (path, f)
    }

    #[test]
    fn nop_roundtrip() {
        if !IoUring::is_supported() {
            eprintln!("skipping: io_uring unavailable on this kernel");
            return;
        }
        let mut ring = IoUring::new(8).unwrap();
        assert_eq!(ring.stats(), RingStats::default());
        ring.prep_nop(7).unwrap();
        let n = ring.submit_and_wait(1).unwrap();
        assert_eq!(n, 1);
        let c = ring.wait_cqe().unwrap();
        assert_eq!(c.user_data, 7);
        assert_eq!(c.result, 0);
        let st = ring.stats();
        assert_eq!((st.submit_calls, st.sqes_submitted), (1, 1));
    }

    #[test]
    fn batched_nops_all_complete() {
        if !IoUring::is_supported() {
            eprintln!("skipping: io_uring unavailable on this kernel");
            return;
        }
        let mut ring = IoUring::new(32).unwrap();
        for i in 0..32 {
            ring.prep_nop(i).unwrap();
        }
        assert_eq!(ring.sq_pending(), 32);
        ring.submit_and_wait(32).unwrap();
        let mut seen: Vec<u64> = (0..32).map(|_| ring.wait_cqe().unwrap().user_data).collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..32).collect::<Vec<_>>());
    }

    #[test]
    fn sq_full_is_reported() {
        if !IoUring::is_supported() {
            eprintln!("skipping: io_uring unavailable on this kernel");
            return;
        }
        let mut ring = IoUring::new(4).unwrap();
        for i in 0..ring.sq_entries() as u64 {
            ring.prep_nop(i).unwrap();
        }
        assert!(ring.prep_nop(99).is_err());
    }

    #[test]
    fn write_then_read_file() {
        if !IoUring::is_supported() {
            eprintln!("skipping: io_uring unavailable on this kernel");
            return;
        }
        let mut ring = IoUring::new(8).unwrap();
        let (path, f) = tmpfile("wr");
        let mut buf = AlignedBuf::zeroed(4096);
        buf.write_at(0, b"io_uring says hi");
        ring.prep_write(f.as_raw_fd(), buf.as_ptr(), 4096, 0, 1).unwrap();
        ring.submit_and_wait(1).unwrap();
        let c = ring.wait_cqe().unwrap();
        assert_eq!(c.bytes().unwrap(), 4096);

        let mut rbuf = AlignedBuf::zeroed(4096);
        ring.prep_read(f.as_raw_fd(), rbuf.as_mut_ptr(), 4096, 0, 2).unwrap();
        ring.submit_and_wait(1).unwrap();
        let c = ring.wait_cqe().unwrap();
        assert_eq!(c.user_data, 2);
        assert_eq!(c.bytes().unwrap(), 4096);
        assert_eq!(&rbuf[..16], b"io_uring says hi");
        drop(f);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn odirect_write_via_ring() {
        if !IoUring::is_supported() {
            eprintln!("skipping: io_uring unavailable on this kernel");
            return;
        }
        use std::os::unix::fs::OpenOptionsExt;
        let path = std::env::temp_dir().join(format!("ckptio-ring-od-{}", std::process::id()));
        let f = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .custom_flags(libc::O_DIRECT)
            .open(&path)
            .unwrap();
        let mut ring = IoUring::new(8).unwrap();
        let mut buf = AlignedBuf::zeroed(8192);
        buf.write_at(4096, b"direct");
        ring.prep_write(f.as_raw_fd(), buf.as_ptr(), 8192, 0, 3).unwrap();
        ring.submit_and_wait(1).unwrap();
        let c = ring.wait_cqe().unwrap();
        assert_eq!(c.bytes().unwrap(), 8192, "O_DIRECT write failed: {:?}", c.bytes());

        // Verify through the page cache.
        let mut check = File::open(&path).unwrap();
        let mut content = Vec::new();
        check.read_to_end(&mut content).unwrap();
        assert_eq!(&content[4096..4102], b"direct");
        drop(f);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn registered_buffers_fixed_io() {
        if !IoUring::is_supported() {
            eprintln!("skipping: io_uring unavailable on this kernel");
            return;
        }
        let mut ring = IoUring::new(8).unwrap();
        let (path, f) = tmpfile("fixed");
        let mut wbuf = AlignedBuf::zeroed(4096);
        let rbuf = AlignedBuf::zeroed(4096);
        wbuf.write_at(0, b"fixed-io");
        let iovecs = [wbuf.as_iovec(), rbuf.as_iovec()];
        ring.register_buffers(&iovecs).unwrap();

        ring.prep_write_fixed(f.as_raw_fd(), wbuf.as_ptr(), 4096, 0, 0, 10).unwrap();
        ring.submit_and_wait(1).unwrap();
        assert_eq!(ring.wait_cqe().unwrap().bytes().unwrap(), 4096);

        // Read back into the second registered buffer.
        let rptr = rbuf.as_ptr() as *mut u8;
        ring.prep_read_fixed(f.as_raw_fd(), rptr, 4096, 0, 1, 11).unwrap();
        ring.submit_and_wait(1).unwrap();
        assert_eq!(ring.wait_cqe().unwrap().bytes().unwrap(), 4096);
        assert_eq!(&rbuf[..8], b"fixed-io");
        drop(f);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn fixed_io_without_registration_rejected() {
        if !IoUring::is_supported() {
            eprintln!("skipping: io_uring unavailable on this kernel");
            return;
        }
        let mut ring = IoUring::new(4).unwrap();
        let buf = AlignedBuf::zeroed(4096);
        assert!(ring
            .prep_write_fixed(1, buf.as_ptr(), 4096, 0, 0, 1)
            .is_err());
    }

    #[test]
    fn fsync_completes() {
        if !IoUring::is_supported() {
            eprintln!("skipping: io_uring unavailable on this kernel");
            return;
        }
        let mut ring = IoUring::new(4).unwrap();
        let (path, f) = tmpfile("fsync");
        ring.prep_fsync(f.as_raw_fd(), 5).unwrap();
        ring.submit_and_wait(1).unwrap();
        let c = ring.wait_cqe().unwrap();
        assert_eq!(c.user_data, 5);
        assert_eq!(c.result, 0);
        drop(f);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn error_surfaces_as_negative_res() {
        if !IoUring::is_supported() {
            eprintln!("skipping: io_uring unavailable on this kernel");
            return;
        }
        let mut ring = IoUring::new(4).unwrap();
        let buf = AlignedBuf::zeroed(4096);
        // fd -1 is invalid → EBADF.
        ring.prep_write(-1, buf.as_ptr(), 4096, 0, 9).unwrap();
        ring.submit_and_wait(1).unwrap();
        let c = ring.wait_cqe().unwrap();
        assert!(c.bytes().is_err());
        assert_eq!(
            c.bytes().unwrap_err().raw_os_error(),
            Some(libc::EBADF)
        );
    }

    #[test]
    fn reap_available_drains() {
        if !IoUring::is_supported() {
            eprintln!("skipping: io_uring unavailable on this kernel");
            return;
        }
        let mut ring = IoUring::new(16).unwrap();
        for i in 0..10 {
            ring.prep_nop(i).unwrap();
        }
        ring.submit_and_wait(10).unwrap();
        let comps = ring.reap_available(100);
        assert_eq!(comps.len(), 10);
        assert!(ring.peek_cqe().is_none());
    }
}
