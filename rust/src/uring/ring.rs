//! The io_uring ring: setup, SQE preparation, submission, completion.
//!
//! This mirrors liburing's `io_uring_queue_init` / `io_uring_get_sqe` /
//! `io_uring_submit(_and_wait)` / `io_uring_{peek,wait}_cqe` API surface,
//! implemented directly over the kernel ABI in [`super::sys`].
//!
//! Memory-ordering protocol (same as liburing):
//! * SQ: the kernel consumes `head` (we load-acquire), we produce `tail`
//!   (store-release after filling SQEs and the index array).
//! * CQ: the kernel produces `tail` (we load-acquire), we consume `head`
//!   (store-release after reading the CQE).
//!
//! Beyond the baseline ring, [`UringFeatures`] opts into the remaining
//! kernel-side accelerations the paper's liburing study leaves on the
//! table — registered (fixed) files, SQPOLL, and linked/drained SQE
//! chains — each degrading gracefully on kernels that refuse them (the
//! same posture as the io_uring→POSIX executor fallback).

#![warn(missing_docs)]

use std::io;
use std::ptr::NonNull;
use std::sync::atomic::{AtomicU32, Ordering};

use crate::error::{Error, Result};

use super::sys::{self, io_uring_cqe, io_uring_params, io_uring_sqe};

/// A reaped completion.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Completion {
    /// The `user_data` attached at prep time (an operation id).
    pub user_data: u64,
    /// Bytes transferred on success, `-errno` on failure.
    pub result: i32,
    /// Kernel CQE flags (unused by the checkpoint engines).
    pub flags: u32,
}

impl Completion {
    /// Bytes transferred, or the operation's error.
    pub fn bytes(&self) -> io::Result<u32> {
        if self.result < 0 {
            Err(io::Error::from_raw_os_error(-self.result))
        } else {
            Ok(self.result as u32)
        }
    }
}

/// Opt-in kernel-acceleration features for a ring (and the backends
/// built on it). Every feature is a *request*: when the running kernel
/// refuses one (EPERM/EINVAL on old kernels, sandboxed runtimes), the
/// ring is rebuilt without it and the effective set reported by
/// [`IoUring::sqpoll_active`] / [`probe_features`] shrinks accordingly —
/// requesting a feature never turns into a hard failure.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct UringFeatures {
    /// Register a sparse fixed-file table at ring creation and route
    /// opens through `IORING_REGISTER_FILES_UPDATE`, skipping the
    /// per-op fdget/fdput refcount dance in the kernel.
    pub fixed_files: bool,
    /// `IORING_SETUP_SQPOLL`: a kernel polling thread consumes the SQ,
    /// making the submit path syscall-free while the thread is awake.
    pub sqpoll: bool,
    /// SQPOLL thread idle timeout (milliseconds) before it sleeps and
    /// must be woken via `IORING_ENTER_SQ_WAKEUP`.
    pub sqpoll_idle_ms: u32,
    /// Chain write→fsync ordering in the kernel with `IOSQE_IO_DRAIN`
    /// instead of draining completions in userspace first.
    pub linked_fsync: bool,
    /// One ring per node shared by all ranks' tier traffic (multiplexed
    /// under a mutex) instead of one ring per writer. Consumed by
    /// `iobackend::shared`, not by the ring itself.
    pub shared_ring: bool,
}

impl Default for UringFeatures {
    fn default() -> Self {
        Self {
            fixed_files: false,
            sqpoll: false,
            sqpoll_idle_ms: 50,
            linked_fsync: false,
            shared_ring: false,
        }
    }
}

impl UringFeatures {
    /// All features off — the PR-5 baseline submit path.
    pub fn none() -> Self {
        Self::default()
    }

    /// Every feature requested (the "raw-speed" configuration).
    pub fn all() -> Self {
        Self {
            fixed_files: true,
            sqpoll: true,
            linked_fsync: true,
            shared_ring: true,
            ..Self::default()
        }
    }

    /// True when any acceleration is requested.
    pub fn any(&self) -> bool {
        self.fixed_files || self.sqpoll || self.linked_fsync || self.shared_ring
    }

    /// Compact `+fixed+sqpoll…` label for bench rows and logs
    /// (`"base"` when nothing is on).
    pub fn label(&self) -> String {
        let mut s = String::new();
        if self.fixed_files {
            s.push_str("+fixed");
        }
        if self.sqpoll {
            s.push_str("+sqpoll");
        }
        if self.linked_fsync {
            s.push_str("+linked");
        }
        if self.shared_ring {
            s.push_str("+shared");
        }
        if s.is_empty() {
            s.push_str("base");
        }
        s
    }
}

/// Which file-descriptor namespace an SQE addresses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FdSlot {
    /// A raw process-level file descriptor.
    Raw(i32),
    /// An index into the ring's registered (fixed) file table; the prep
    /// sets `IOSQE_FIXED_FILE`.
    Fixed(u32),
}

/// Per-SQE modifier flags for the `prep_*_opts` variants.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SqeOpts {
    /// `IOSQE_IO_LINK`: the next SQE starts only after this completes.
    pub link: bool,
    /// `IOSQE_IO_DRAIN`: this SQE starts only after all prior SQEs
    /// complete (the kernel-side write→fsync ordering barrier).
    pub drain: bool,
}

struct Mmap {
    ptr: NonNull<u8>,
    len: usize,
}

impl Mmap {
    fn map(fd: i32, len: usize, offset: libc::off_t) -> io::Result<Self> {
        // SAFETY: standard mmap of an io_uring region; kernel validates
        // len/offset against the ring geometry.
        let ptr = unsafe {
            libc::mmap(
                std::ptr::null_mut(),
                len,
                libc::PROT_READ | libc::PROT_WRITE,
                libc::MAP_SHARED | libc::MAP_POPULATE,
                fd,
                offset,
            )
        };
        if ptr == libc::MAP_FAILED {
            return Err(io::Error::last_os_error());
        }
        Ok(Self {
            ptr: NonNull::new(ptr as *mut u8).expect("mmap returned null"),
            len,
        })
    }

    /// Pointer to `offset` bytes into the mapping.
    fn at(&self, offset: u32) -> *mut u8 {
        debug_assert!((offset as usize) < self.len);
        // SAFETY: offset < len per ring geometry.
        unsafe { self.ptr.as_ptr().add(offset as usize) }
    }
}

impl Drop for Mmap {
    fn drop(&mut self) {
        // SAFETY: exactly the region we mapped.
        unsafe { libc::munmap(self.ptr.as_ptr() as *mut libc::c_void, self.len) };
    }
}

/// Submission-queue view into the mapped ring.
struct Sq {
    /// Keeps the SQ ring mapping alive (fields below point into it).
    _ring: Mmap,
    head: *const AtomicU32,
    tail: *const AtomicU32,
    ring_mask: u32,
    ring_entries: u32,
    array: *mut u32,
    /// SQ flags word (IORING_SQ_NEED_WAKEUP under SQPOLL).
    flags: *const AtomicU32,
    sqes: Mmap,
    /// Our local (not yet published) tail.
    sqe_tail: u32,
    /// Local cache of the published tail (for space accounting).
    sqe_head: u32,
}

/// Completion-queue view into the mapped ring.
struct Cq {
    /// Present only when the kernel lacks IORING_FEAT_SINGLE_MMAP (we keep
    /// the separate mapping alive here).
    _ring: Option<Mmap>,
    head: *const AtomicU32,
    tail: *const AtomicU32,
    ring_mask: u32,
    cqes: *const io_uring_cqe,
}

/// An io_uring instance.
///
/// Not `Sync`: one ring per thread, the same discipline liburing
/// recommends and the checkpoint engines follow (ring-per-rank).
pub struct IoUring {
    fd: i32,
    sq: Sq,
    cq: Cq,
    params: io_uring_params,
    registered_buffers: bool,
    registered_files: bool,
    /// Slots in the registered fixed-file table (0 = none).
    fixed_file_slots: u32,
    /// SQPOLL granted and kept (see `new_with` for the keep rules).
    sqpoll: bool,
    stats: RingStats,
}

/// Submission-batching tallies for one ring: how many `io_uring_enter`
/// submission calls were made and how many SQEs they carried. The ratio
/// is the batching efficiency the aggregation strategies trade on (a
/// plain per-thread counter — the ring is not `Sync`). Under SQPOLL,
/// `sqes_submitted` keeps growing while `submit_calls` only counts the
/// wakeup syscalls — the gap *is* the zero-syscall submit win.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RingStats {
    /// `io_uring_enter` calls that submitted at least one SQE (under
    /// SQPOLL: wakeup calls made while SQEs were pending).
    pub submit_calls: u64,
    /// SQEs published to the kernel.
    pub sqes_submitted: u64,
    /// `IORING_ENTER_SQ_WAKEUP` calls issued to rouse an idle SQPOLL
    /// thread.
    pub sqpoll_wakeups: u64,
    /// Ops issued against a registered (fixed) file-table slot.
    pub fixed_file_ops: u64,
    /// Fsyncs ordered in-kernel via `IOSQE_IO_DRAIN`/`IOSQE_IO_LINK`
    /// instead of a userspace completion round-trip.
    pub linked_fsyncs: u64,
}

impl RingStats {
    /// Accumulate another tally into this one (used when draining
    /// per-ring stats into the trace counters).
    pub fn merge(&mut self, other: &RingStats) {
        self.submit_calls += other.submit_calls;
        self.sqes_submitted += other.sqes_submitted;
        self.sqpoll_wakeups += other.sqpoll_wakeups;
        self.fixed_file_ops += other.fixed_file_ops;
        self.linked_fsyncs += other.linked_fsyncs;
    }
}

// SAFETY: all raw pointers reference the ring mmaps owned by this value;
// moving the struct between threads is fine (no thread affinity), it is
// just not usable concurrently (not Sync).
unsafe impl Send for IoUring {}

impl IoUring {
    /// Does this kernel support io_uring? Probed once per process.
    /// Sandboxed runtimes (gVisor, seccomp-filtered containers) and
    /// pre-5.1 kernels return ENOSYS/EPERM from `io_uring_setup`; the
    /// real executor uses this to degrade gracefully to POSIX.
    pub fn is_supported() -> bool {
        static SUPPORTED: once_cell::sync::Lazy<bool> =
            once_cell::sync::Lazy::new(|| IoUring::new(2).is_ok());
        *SUPPORTED
    }

    /// Create a ring with at least `entries` SQ slots (rounded up to a
    /// power of two by the kernel).
    pub fn new(entries: u32) -> Result<Self> {
        let mut params = io_uring_params::default();
        let fd = sys::io_uring_setup(entries, &mut params).map_err(|e| Error::Uring {
            op: "io_uring_setup",
            source: e,
        })?;
        match Self::map_rings(fd, params) {
            Ok(ring) => Ok(ring),
            Err(e) => {
                // SAFETY: fd from io_uring_setup, not yet wrapped.
                unsafe { libc::close(fd) };
                Err(e)
            }
        }
    }

    /// Create a ring with the requested [`UringFeatures`], degrading
    /// gracefully when the kernel refuses any of them:
    ///
    /// * SQPOLL setup failing with EPERM (unprivileged pre-5.11) or
    ///   EINVAL (no SQPOLL at all) falls back to a plain ring.
    /// * An SQPOLL ring *without* `IORING_FEAT_SQPOLL_NONFIXED` can only
    ///   issue fixed-file ops; unless `fixed_files` is also requested
    ///   (so every op will carry `IOSQE_FIXED_FILE`), the SQPOLL ring is
    ///   torn down and a plain ring used instead — raw-fd ops on such a
    ///   ring would all fail with EBADF.
    ///
    /// Fixed-file table registration is the *caller's* second step (see
    /// [`Self::register_files_sparse`]) and has its own fallback. Check
    /// [`Self::sqpoll_active`] for what was actually granted.
    pub fn new_with(entries: u32, features: &UringFeatures) -> Result<Self> {
        if features.sqpoll {
            let mut params = io_uring_params {
                flags: sys::IORING_SETUP_SQPOLL,
                sq_thread_idle: features.sqpoll_idle_ms.max(1),
                ..io_uring_params::default()
            };
            if let Ok(fd) = sys::io_uring_setup(entries, &mut params) {
                match Self::map_rings(fd, params) {
                    Ok(mut ring) => {
                        ring.sqpoll = true;
                        let nonfixed =
                            ring.params.features & sys::IORING_FEAT_SQPOLL_NONFIXED != 0;
                        if nonfixed || features.fixed_files {
                            return Ok(ring);
                        }
                        // Pre-5.11 SQPOLL + raw fds would EBADF on every
                        // op; drop the ring and build a plain one.
                        drop(ring);
                    }
                    Err(_) => {
                        // SAFETY: fd from io_uring_setup, not yet wrapped.
                        unsafe { libc::close(fd) };
                    }
                }
            }
        }
        Self::new(entries)
    }

    fn map_rings(fd: i32, params: io_uring_params) -> Result<Self> {
        let sq_ring_len =
            params.sq_off.array as usize + params.sq_entries as usize * std::mem::size_of::<u32>();
        let cq_ring_len = params.cq_off.cqes as usize
            + params.cq_entries as usize * std::mem::size_of::<io_uring_cqe>();
        let single = params.features & sys::IORING_FEAT_SINGLE_MMAP != 0;
        let map_err = |op: &'static str| move |e: io::Error| Error::Uring { op, source: e };

        let sq_map_len = if single {
            sq_ring_len.max(cq_ring_len)
        } else {
            sq_ring_len
        };
        let sq_ring = Mmap::map(fd, sq_map_len, sys::IORING_OFF_SQ_RING)
            .map_err(map_err("mmap sq_ring"))?;
        let (cq_ring, cq_base): (Option<Mmap>, *mut u8) = if single {
            (None, sq_ring.ptr.as_ptr())
        } else {
            let m = Mmap::map(fd, cq_ring_len, sys::IORING_OFF_CQ_RING)
                .map_err(map_err("mmap cq_ring"))?;
            let p = m.ptr.as_ptr();
            (Some(m), p)
        };
        let sqes = Mmap::map(
            fd,
            params.sq_entries as usize * std::mem::size_of::<io_uring_sqe>(),
            sys::IORING_OFF_SQES,
        )
        .map_err(map_err("mmap sqes"))?;

        // SAFETY: all offsets come from the kernel's ring geometry.
        let sq = unsafe {
            Sq {
                head: sq_ring.at(params.sq_off.head) as *const AtomicU32,
                tail: sq_ring.at(params.sq_off.tail) as *const AtomicU32,
                ring_mask: *(sq_ring.at(params.sq_off.ring_mask) as *const u32),
                ring_entries: *(sq_ring.at(params.sq_off.ring_entries) as *const u32),
                array: sq_ring.at(params.sq_off.array) as *mut u32,
                flags: sq_ring.at(params.sq_off.flags) as *const AtomicU32,
                sqe_tail: (*(sq_ring.at(params.sq_off.tail) as *const AtomicU32))
                    .load(Ordering::Relaxed),
                sqe_head: (*(sq_ring.at(params.sq_off.head) as *const AtomicU32))
                    .load(Ordering::Relaxed),
                _ring: sq_ring,
                sqes,
            }
        };
        let cq = unsafe {
            Cq {
                head: cq_base.add(params.cq_off.head as usize) as *const AtomicU32,
                tail: cq_base.add(params.cq_off.tail as usize) as *const AtomicU32,
                ring_mask: *(cq_base.add(params.cq_off.ring_mask as usize) as *const u32),
                cqes: cq_base.add(params.cq_off.cqes as usize) as *const io_uring_cqe,
                _ring: cq_ring,
            }
        };
        Ok(Self {
            fd,
            sq,
            cq,
            params,
            registered_buffers: false,
            registered_files: false,
            fixed_file_slots: 0,
            sqpoll: false,
            stats: RingStats::default(),
        })
    }

    /// Submission-batching tallies accumulated over the ring's lifetime.
    pub fn stats(&self) -> RingStats {
        self.stats
    }

    /// SQ capacity (entries).
    pub fn sq_entries(&self) -> u32 {
        self.sq.ring_entries
    }

    /// Unsubmitted + in-kernel slots currently free in the SQ.
    pub fn sq_space_left(&self) -> u32 {
        // SAFETY: head points into the live SQ ring mmap.
        let head = unsafe { (*self.sq.head).load(Ordering::Acquire) };
        self.sq.ring_entries - self.sq.sqe_tail.wrapping_sub(head)
    }

    /// Number of prepared-but-unsubmitted SQEs.
    pub fn sq_pending(&self) -> u32 {
        self.sq.sqe_tail.wrapping_sub(self.sq.sqe_head)
    }

    fn next_sqe(&mut self) -> Result<&mut io_uring_sqe> {
        if self.sq_space_left() == 0 {
            return Err(Error::Uring {
                op: "get_sqe",
                source: io::Error::new(io::ErrorKind::WouldBlock, "submission queue full"),
            });
        }
        let idx = (self.sq.sqe_tail & self.sq.ring_mask) as usize;
        self.sq.sqe_tail = self.sq.sqe_tail.wrapping_add(1);
        // SAFETY: idx < ring_entries; sqes mmap holds ring_entries SQEs.
        let sqe = unsafe {
            &mut *(self.sq.sqes.ptr.as_ptr() as *mut io_uring_sqe).add(idx)
        };
        *sqe = io_uring_sqe::default();
        Ok(sqe)
    }

    /// Queue a NOP (used by tests and the microbenchmark to measure pure
    /// submission overhead).
    pub fn prep_nop(&mut self, user_data: u64) -> Result<()> {
        let sqe = self.next_sqe()?;
        sqe.opcode = sys::IORING_OP_NOP;
        sqe.user_data = user_data;
        Ok(())
    }

    /// Apply an [`FdSlot`] target and [`SqeOpts`] modifiers to a
    /// prepared SQE.
    fn apply_target(sqe: &mut io_uring_sqe, fd: FdSlot, opts: SqeOpts) {
        match fd {
            FdSlot::Raw(raw) => sqe.fd = raw,
            FdSlot::Fixed(idx) => {
                sqe.fd = idx as i32;
                sqe.flags |= sys::IOSQE_FIXED_FILE;
            }
        }
        if opts.link {
            sqe.flags |= sys::IOSQE_IO_LINK;
        }
        if opts.drain {
            sqe.flags |= sys::IOSQE_IO_DRAIN;
        }
    }

    /// Queue a positional write of `len` bytes from `buf` at file `offset`.
    ///
    /// # Safety contract
    /// `buf` must stay alive and unmoved until the completion for
    /// `user_data` is reaped (enforced by the owning backend).
    pub fn prep_write(
        &mut self,
        fd: i32,
        buf: *const u8,
        len: u32,
        offset: u64,
        user_data: u64,
    ) -> Result<()> {
        self.prep_write_opts(FdSlot::Raw(fd), buf, len, offset, SqeOpts::default(), user_data)
    }

    /// [`Self::prep_write`] addressing an [`FdSlot`] with [`SqeOpts`]
    /// modifiers. A `Fixed` slot requires a registered file table (see
    /// [`Self::register_files_sparse`]); the same buffer-lifetime
    /// contract as `prep_write` applies.
    pub fn prep_write_opts(
        &mut self,
        fd: FdSlot,
        buf: *const u8,
        len: u32,
        offset: u64,
        opts: SqeOpts,
        user_data: u64,
    ) -> Result<()> {
        if matches!(fd, FdSlot::Fixed(_)) && !self.registered_files {
            return Err(Error::msg("fixed-file op without a registered file table"));
        }
        let sqe = self.next_sqe()?;
        sqe.opcode = sys::IORING_OP_WRITE;
        sqe.addr = buf as u64;
        sqe.len = len;
        sqe.off = offset;
        sqe.user_data = user_data;
        Self::apply_target(sqe, fd, opts);
        if matches!(fd, FdSlot::Fixed(_)) {
            self.stats.fixed_file_ops += 1;
        }
        Ok(())
    }

    /// Queue a positional read into `buf`.
    pub fn prep_read(
        &mut self,
        fd: i32,
        buf: *mut u8,
        len: u32,
        offset: u64,
        user_data: u64,
    ) -> Result<()> {
        self.prep_read_opts(FdSlot::Raw(fd), buf, len, offset, SqeOpts::default(), user_data)
    }

    /// [`Self::prep_read`] addressing an [`FdSlot`] with [`SqeOpts`]
    /// modifiers.
    pub fn prep_read_opts(
        &mut self,
        fd: FdSlot,
        buf: *mut u8,
        len: u32,
        offset: u64,
        opts: SqeOpts,
        user_data: u64,
    ) -> Result<()> {
        if matches!(fd, FdSlot::Fixed(_)) && !self.registered_files {
            return Err(Error::msg("fixed-file op without a registered file table"));
        }
        let sqe = self.next_sqe()?;
        sqe.opcode = sys::IORING_OP_READ;
        sqe.addr = buf as u64;
        sqe.len = len;
        sqe.off = offset;
        sqe.user_data = user_data;
        Self::apply_target(sqe, fd, opts);
        if matches!(fd, FdSlot::Fixed(_)) {
            self.stats.fixed_file_ops += 1;
        }
        Ok(())
    }

    /// Positional write from a registered buffer (`IORING_OP_WRITE_FIXED`).
    pub fn prep_write_fixed(
        &mut self,
        fd: i32,
        buf: *const u8,
        len: u32,
        offset: u64,
        buf_index: u16,
        user_data: u64,
    ) -> Result<()> {
        if !self.registered_buffers {
            return Err(Error::msg("write_fixed without registered buffers"));
        }
        let sqe = self.next_sqe()?;
        sqe.opcode = sys::IORING_OP_WRITE_FIXED;
        sqe.fd = fd;
        sqe.addr = buf as u64;
        sqe.len = len;
        sqe.off = offset;
        sqe.buf_index = buf_index;
        sqe.user_data = user_data;
        Ok(())
    }

    /// Positional read into a registered buffer (`IORING_OP_READ_FIXED`).
    pub fn prep_read_fixed(
        &mut self,
        fd: i32,
        buf: *mut u8,
        len: u32,
        offset: u64,
        buf_index: u16,
        user_data: u64,
    ) -> Result<()> {
        if !self.registered_buffers {
            return Err(Error::msg("read_fixed without registered buffers"));
        }
        let sqe = self.next_sqe()?;
        sqe.opcode = sys::IORING_OP_READ_FIXED;
        sqe.fd = fd;
        sqe.addr = buf as u64;
        sqe.len = len;
        sqe.off = offset;
        sqe.buf_index = buf_index;
        sqe.user_data = user_data;
        Ok(())
    }

    /// Queue an fsync.
    pub fn prep_fsync(&mut self, fd: i32, user_data: u64) -> Result<()> {
        self.prep_fsync_opts(FdSlot::Raw(fd), SqeOpts::default(), user_data)
    }

    /// [`Self::prep_fsync`] addressing an [`FdSlot`] with [`SqeOpts`]
    /// modifiers. With `opts.drain` (or as the tail of a `link` chain)
    /// the kernel orders the fsync after every prior SQE, so the caller
    /// needs no userspace drain before queueing it.
    pub fn prep_fsync_opts(&mut self, fd: FdSlot, opts: SqeOpts, user_data: u64) -> Result<()> {
        if matches!(fd, FdSlot::Fixed(_)) && !self.registered_files {
            return Err(Error::msg("fixed-file op without a registered file table"));
        }
        let sqe = self.next_sqe()?;
        sqe.opcode = sys::IORING_OP_FSYNC;
        sqe.user_data = user_data;
        Self::apply_target(sqe, fd, opts);
        if matches!(fd, FdSlot::Fixed(_)) {
            self.stats.fixed_file_ops += 1;
        }
        if opts.drain || opts.link {
            self.stats.linked_fsyncs += 1;
        }
        Ok(())
    }

    /// Publish prepared SQEs to the kernel-visible tail. Returns how many
    /// were published.
    fn flush_sq(&mut self) -> u32 {
        let to_submit = self.sq.sqe_tail.wrapping_sub(self.sq.sqe_head);
        if to_submit == 0 {
            return 0;
        }
        let mask = self.sq.ring_mask;
        let mut tail = self.sq.sqe_head;
        for _ in 0..to_submit {
            let idx = tail & mask;
            // SAFETY: array has ring_entries u32 slots; idx is masked.
            unsafe { *self.sq.array.add(idx as usize) = idx };
            tail = tail.wrapping_add(1);
        }
        debug_assert_eq!(tail, self.sq.sqe_tail);
        // SAFETY: tail points into the live SQ ring mmap. Release makes
        // the SQE writes visible to the kernel before the new tail.
        unsafe { (*self.sq.tail).store(tail, Ordering::Release) };
        self.sq.sqe_head = tail;
        to_submit
    }

    /// Submit all prepared SQEs. Returns the number the kernel consumed.
    pub fn submit(&mut self) -> Result<u32> {
        self.submit_and_wait(0)
    }

    /// Submit and block until at least `wait_for` completions are posted.
    ///
    /// Under SQPOLL the publish is the store-release of the SQ tail —
    /// the kernel thread picks SQEs up without a syscall. `io_uring_enter`
    /// is then only issued to wake an idle poller (`IORING_SQ_NEED_WAKEUP`
    /// set in the SQ flags) or to wait for completions; `submit_calls`
    /// counts just those wakeups, which is what makes the
    /// submit-calls-per-SQE trace ratio collapse in SQPOLL mode.
    pub fn submit_and_wait(&mut self, wait_for: u32) -> Result<u32> {
        let to_submit = self.flush_sq();
        if to_submit == 0 && wait_for == 0 {
            return Ok(0);
        }
        if self.sqpoll {
            self.stats.sqes_submitted += u64::from(to_submit);
            // SAFETY: flags points into the live SQ ring mmap.
            let need_wakeup = unsafe {
                (*self.sq.flags).load(Ordering::Acquire) & sys::IORING_SQ_NEED_WAKEUP != 0
            };
            if need_wakeup || wait_for > 0 {
                let mut flags = 0;
                if need_wakeup {
                    flags |= sys::IORING_ENTER_SQ_WAKEUP;
                }
                if wait_for > 0 {
                    flags |= sys::IORING_ENTER_GETEVENTS;
                }
                sys::io_uring_enter(self.fd, to_submit, wait_for, flags).map_err(|e| {
                    Error::Uring {
                        op: "io_uring_enter(sqpoll)",
                        source: e,
                    }
                })?;
                if need_wakeup {
                    self.stats.sqpoll_wakeups += 1;
                    if to_submit > 0 {
                        self.stats.submit_calls += 1;
                    }
                }
            }
            return Ok(to_submit);
        }
        let flags = if wait_for > 0 {
            sys::IORING_ENTER_GETEVENTS
        } else {
            0
        };
        let submitted =
            sys::io_uring_enter(self.fd, to_submit, wait_for, flags).map_err(|e| Error::Uring {
                op: "io_uring_enter",
                source: e,
            })?;
        if to_submit > 0 {
            self.stats.submit_calls += 1;
            self.stats.sqes_submitted += u64::from(to_submit);
        }
        Ok(submitted)
    }

    /// Reap one completion if available, without blocking.
    pub fn peek_cqe(&mut self) -> Option<Completion> {
        // SAFETY: head/tail point into the live CQ ring mmap.
        let head = unsafe { (*self.cq.head).load(Ordering::Relaxed) };
        let tail = unsafe { (*self.cq.tail).load(Ordering::Acquire) };
        if head == tail {
            return None;
        }
        let idx = (head & self.cq.ring_mask) as usize;
        // SAFETY: idx masked into the CQE array.
        let cqe = unsafe { *self.cq.cqes.add(idx) };
        // SAFETY: publishing consumption back to the kernel.
        unsafe { (*self.cq.head).store(head.wrapping_add(1), Ordering::Release) };
        Some(Completion {
            user_data: cqe.user_data,
            result: cqe.res,
            flags: cqe.flags,
        })
    }

    /// Block until a completion is available and return it.
    pub fn wait_cqe(&mut self) -> Result<Completion> {
        loop {
            if let Some(c) = self.peek_cqe() {
                return Ok(c);
            }
            sys::io_uring_enter(self.fd, 0, 1, sys::IORING_ENTER_GETEVENTS).map_err(|e| {
                Error::Uring {
                    op: "io_uring_enter(wait)",
                    source: e,
                }
            })?;
        }
    }

    /// Reap up to `max` immediately-available completions.
    pub fn reap_available(&mut self, max: usize) -> Vec<Completion> {
        let mut out = Vec::new();
        while out.len() < max {
            match self.peek_cqe() {
                Some(c) => out.push(c),
                None => break,
            }
        }
        out
    }

    /// Register fixed buffers for zero-copy `*_FIXED` ops.
    pub fn register_buffers(&mut self, iovecs: &[libc::iovec]) -> Result<()> {
        sys::io_uring_register(
            self.fd,
            sys::IORING_REGISTER_BUFFERS,
            iovecs.as_ptr() as *const libc::c_void,
            iovecs.len() as u32,
        )
        .map_err(|e| Error::Uring {
            op: "register_buffers",
            source: e,
        })?;
        self.registered_buffers = true;
        Ok(())
    }

    /// Unregister the fixed buffer set registered by
    /// [`Self::register_buffers`]; subsequent `*_FIXED` preps are
    /// rejected again.
    pub fn unregister_buffers(&mut self) -> Result<()> {
        sys::io_uring_register(self.fd, sys::IORING_UNREGISTER_BUFFERS, std::ptr::null(), 0)
            .map_err(|e| Error::Uring {
                op: "unregister_buffers",
                source: e,
            })?;
        self.registered_buffers = false;
        Ok(())
    }

    /// Register a fixed file set.
    ///
    /// # Safety contract
    /// The kernel holds its own reference on every registered fd until
    /// it is unregistered or the ring closes, so the files may be
    /// dropped by the caller — but a slot must not be re-pointed at a
    /// different file while ops addressing it are in flight.
    pub fn register_files(&mut self, fds: &[i32]) -> Result<()> {
        sys::io_uring_register(
            self.fd,
            sys::IORING_REGISTER_FILES,
            fds.as_ptr() as *const libc::c_void,
            fds.len() as u32,
        )
        .map_err(|e| Error::Uring {
            op: "register_files",
            source: e,
        })?;
        self.registered_files = true;
        self.fixed_file_slots = fds.len() as u32;
        Ok(())
    }

    /// Register a sparse fixed-file table of `slots` empty (-1) entries,
    /// to be populated incrementally with
    /// [`Self::update_registered_file`]. Old kernels (< 5.5) reject
    /// sparse tables; callers treat the error as "feature unavailable"
    /// and stay on raw fds.
    pub fn register_files_sparse(&mut self, slots: u32) -> Result<()> {
        let fds = vec![-1i32; slots as usize];
        self.register_files(&fds)
    }

    /// Point registered-file slot `index` at `fd` (or clear it with
    /// -1) via `IORING_REGISTER_FILES_UPDATE`, without quiescing the
    /// ring. The same in-flight contract as [`Self::register_files`]
    /// applies to the replaced slot.
    pub fn update_registered_file(&mut self, index: u32, fd: i32) -> Result<()> {
        if !self.registered_files || index >= self.fixed_file_slots {
            return Err(Error::msg("fixed-file update outside the registered table"));
        }
        let fds = [fd];
        let upd = sys::io_uring_files_update {
            offset: index,
            resv: 0,
            fds: fds.as_ptr() as u64,
        };
        sys::io_uring_register(
            self.fd,
            sys::IORING_REGISTER_FILES_UPDATE,
            &upd as *const sys::io_uring_files_update as *const libc::c_void,
            1,
        )
        .map_err(|e| Error::Uring {
            op: "register_files_update",
            source: e,
        })
    }

    /// Drop the registered fixed-file table.
    pub fn unregister_files(&mut self) -> Result<()> {
        sys::io_uring_register(self.fd, sys::IORING_UNREGISTER_FILES, std::ptr::null(), 0)
            .map_err(|e| Error::Uring {
                op: "unregister_files",
                source: e,
            })?;
        self.registered_files = false;
        self.fixed_file_slots = 0;
        Ok(())
    }

    /// Is a fixed file table registered on this ring?
    pub fn has_registered_files(&self) -> bool {
        self.registered_files
    }

    /// Slots in the registered fixed-file table (0 when none).
    pub fn fixed_file_slots(&self) -> u32 {
        self.fixed_file_slots
    }

    /// Was SQPOLL requested, granted by the kernel, *and* kept after
    /// the `IORING_FEAT_SQPOLL_NONFIXED` check in [`Self::new_with`]?
    pub fn sqpoll_active(&self) -> bool {
        self.sqpoll
    }

    /// Does this kernel allow raw (non-registered) fds under SQPOLL
    /// (`IORING_FEAT_SQPOLL_NONFIXED`, kernel >= 5.11)?
    pub fn supports_sqpoll_nonfixed(&self) -> bool {
        self.params.features & sys::IORING_FEAT_SQPOLL_NONFIXED != 0
    }

    /// Kernel-reported features bitmask.
    pub fn features(&self) -> u32 {
        self.params.features
    }
}

/// Probe which of the requested features this kernel actually grants,
/// by building (and immediately dropping) a small ring the same way
/// [`crate::iobackend::UringIo`] would. Benches and tests use this to
/// label rows and skip feature legs honestly; `shared_ring` and
/// `linked_fsync` need no kernel support beyond io_uring itself.
pub fn probe_features(requested: UringFeatures) -> UringFeatures {
    let mut granted = UringFeatures {
        sqpoll_idle_ms: requested.sqpoll_idle_ms,
        ..UringFeatures::none()
    };
    if !IoUring::is_supported() {
        return granted;
    }
    granted.linked_fsync = requested.linked_fsync;
    granted.shared_ring = requested.shared_ring;
    match IoUring::new_with(8, &requested) {
        Ok(mut ring) => {
            granted.sqpoll = ring.sqpoll_active();
            if requested.fixed_files {
                granted.fixed_files = ring.register_files_sparse(8).is_ok();
            }
            // An SQPOLL ring kept only on the promise of fixed files is
            // unusable if the sparse registration then failed.
            if granted.sqpoll && !ring.supports_sqpoll_nonfixed() && !granted.fixed_files {
                granted.sqpoll = false;
            }
        }
        Err(_) => return UringFeatures::none(),
    }
    granted
}

impl Drop for IoUring {
    fn drop(&mut self) {
        // SAFETY: fd owned by this ring.
        unsafe { libc::close(self.fd) };
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::uring::buf::AlignedBuf;
    use std::fs::{File, OpenOptions};
    use std::io::Read;
    use std::os::unix::io::AsRawFd;

    fn tmpfile(name: &str) -> (std::path::PathBuf, File) {
        let path = std::env::temp_dir().join(format!("ckptio-ring-{name}-{}", std::process::id()));
        let f = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(&path)
            .unwrap();
        (path, f)
    }

    #[test]
    fn nop_roundtrip() {
        if !IoUring::is_supported() {
            eprintln!("skipping: io_uring unavailable on this kernel");
            return;
        }
        let mut ring = IoUring::new(8).unwrap();
        assert_eq!(ring.stats(), RingStats::default());
        ring.prep_nop(7).unwrap();
        let n = ring.submit_and_wait(1).unwrap();
        assert_eq!(n, 1);
        let c = ring.wait_cqe().unwrap();
        assert_eq!(c.user_data, 7);
        assert_eq!(c.result, 0);
        let st = ring.stats();
        assert_eq!((st.submit_calls, st.sqes_submitted), (1, 1));
    }

    #[test]
    fn batched_nops_all_complete() {
        if !IoUring::is_supported() {
            eprintln!("skipping: io_uring unavailable on this kernel");
            return;
        }
        let mut ring = IoUring::new(32).unwrap();
        for i in 0..32 {
            ring.prep_nop(i).unwrap();
        }
        assert_eq!(ring.sq_pending(), 32);
        ring.submit_and_wait(32).unwrap();
        let mut seen: Vec<u64> = (0..32).map(|_| ring.wait_cqe().unwrap().user_data).collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..32).collect::<Vec<_>>());
    }

    #[test]
    fn sq_full_is_reported() {
        if !IoUring::is_supported() {
            eprintln!("skipping: io_uring unavailable on this kernel");
            return;
        }
        let mut ring = IoUring::new(4).unwrap();
        for i in 0..ring.sq_entries() as u64 {
            ring.prep_nop(i).unwrap();
        }
        assert!(ring.prep_nop(99).is_err());
    }

    #[test]
    fn write_then_read_file() {
        if !IoUring::is_supported() {
            eprintln!("skipping: io_uring unavailable on this kernel");
            return;
        }
        let mut ring = IoUring::new(8).unwrap();
        let (path, f) = tmpfile("wr");
        let mut buf = AlignedBuf::zeroed(4096);
        buf.write_at(0, b"io_uring says hi");
        ring.prep_write(f.as_raw_fd(), buf.as_ptr(), 4096, 0, 1).unwrap();
        ring.submit_and_wait(1).unwrap();
        let c = ring.wait_cqe().unwrap();
        assert_eq!(c.bytes().unwrap(), 4096);

        let mut rbuf = AlignedBuf::zeroed(4096);
        ring.prep_read(f.as_raw_fd(), rbuf.as_mut_ptr(), 4096, 0, 2).unwrap();
        ring.submit_and_wait(1).unwrap();
        let c = ring.wait_cqe().unwrap();
        assert_eq!(c.user_data, 2);
        assert_eq!(c.bytes().unwrap(), 4096);
        assert_eq!(&rbuf[..16], b"io_uring says hi");
        drop(f);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn odirect_write_via_ring() {
        if !IoUring::is_supported() {
            eprintln!("skipping: io_uring unavailable on this kernel");
            return;
        }
        use std::os::unix::fs::OpenOptionsExt;
        let path = std::env::temp_dir().join(format!("ckptio-ring-od-{}", std::process::id()));
        let f = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .custom_flags(libc::O_DIRECT)
            .open(&path)
            .unwrap();
        let mut ring = IoUring::new(8).unwrap();
        let mut buf = AlignedBuf::zeroed(8192);
        buf.write_at(4096, b"direct");
        ring.prep_write(f.as_raw_fd(), buf.as_ptr(), 8192, 0, 3).unwrap();
        ring.submit_and_wait(1).unwrap();
        let c = ring.wait_cqe().unwrap();
        assert_eq!(c.bytes().unwrap(), 8192, "O_DIRECT write failed: {:?}", c.bytes());

        // Verify through the page cache.
        let mut check = File::open(&path).unwrap();
        let mut content = Vec::new();
        check.read_to_end(&mut content).unwrap();
        assert_eq!(&content[4096..4102], b"direct");
        drop(f);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn registered_buffers_fixed_io() {
        if !IoUring::is_supported() {
            eprintln!("skipping: io_uring unavailable on this kernel");
            return;
        }
        let mut ring = IoUring::new(8).unwrap();
        let (path, f) = tmpfile("fixed");
        let mut wbuf = AlignedBuf::zeroed(4096);
        let rbuf = AlignedBuf::zeroed(4096);
        wbuf.write_at(0, b"fixed-io");
        let iovecs = [wbuf.as_iovec(), rbuf.as_iovec()];
        ring.register_buffers(&iovecs).unwrap();

        ring.prep_write_fixed(f.as_raw_fd(), wbuf.as_ptr(), 4096, 0, 0, 10).unwrap();
        ring.submit_and_wait(1).unwrap();
        assert_eq!(ring.wait_cqe().unwrap().bytes().unwrap(), 4096);

        // Read back into the second registered buffer.
        let rptr = rbuf.as_ptr() as *mut u8;
        ring.prep_read_fixed(f.as_raw_fd(), rptr, 4096, 0, 1, 11).unwrap();
        ring.submit_and_wait(1).unwrap();
        assert_eq!(ring.wait_cqe().unwrap().bytes().unwrap(), 4096);
        assert_eq!(&rbuf[..8], b"fixed-io");
        drop(f);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn fixed_io_without_registration_rejected() {
        if !IoUring::is_supported() {
            eprintln!("skipping: io_uring unavailable on this kernel");
            return;
        }
        let mut ring = IoUring::new(4).unwrap();
        let buf = AlignedBuf::zeroed(4096);
        assert!(ring
            .prep_write_fixed(1, buf.as_ptr(), 4096, 0, 0, 1)
            .is_err());
    }

    #[test]
    fn fsync_completes() {
        if !IoUring::is_supported() {
            eprintln!("skipping: io_uring unavailable on this kernel");
            return;
        }
        let mut ring = IoUring::new(4).unwrap();
        let (path, f) = tmpfile("fsync");
        ring.prep_fsync(f.as_raw_fd(), 5).unwrap();
        ring.submit_and_wait(1).unwrap();
        let c = ring.wait_cqe().unwrap();
        assert_eq!(c.user_data, 5);
        assert_eq!(c.result, 0);
        drop(f);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn error_surfaces_as_negative_res() {
        if !IoUring::is_supported() {
            eprintln!("skipping: io_uring unavailable on this kernel");
            return;
        }
        let mut ring = IoUring::new(4).unwrap();
        let buf = AlignedBuf::zeroed(4096);
        // fd -1 is invalid → EBADF.
        ring.prep_write(-1, buf.as_ptr(), 4096, 0, 9).unwrap();
        ring.submit_and_wait(1).unwrap();
        let c = ring.wait_cqe().unwrap();
        assert!(c.bytes().is_err());
        assert_eq!(
            c.bytes().unwrap_err().raw_os_error(),
            Some(libc::EBADF)
        );
    }

    #[test]
    fn features_label_composition() {
        assert_eq!(UringFeatures::none().label(), "base");
        assert_eq!(UringFeatures::all().label(), "+fixed+sqpoll+linked+shared");
        assert!(!UringFeatures::none().any());
        assert!(UringFeatures::all().any());
    }

    #[test]
    fn fixed_file_roundtrip_via_registered_slot() {
        if !IoUring::is_supported() {
            eprintln!("skipping: io_uring unavailable on this kernel");
            return;
        }
        let mut ring = IoUring::new(8).unwrap();
        if ring.register_files_sparse(4).is_err() {
            eprintln!("skipping: sparse fixed-file tables unavailable");
            return;
        }
        let (path, f) = tmpfile("fixedfile");
        ring.update_registered_file(2, f.as_raw_fd()).unwrap();

        let mut wbuf = AlignedBuf::zeroed(4096);
        wbuf.write_at(0, b"fixed-file slot 2");
        ring.prep_write_opts(
            FdSlot::Fixed(2),
            wbuf.as_ptr(),
            4096,
            0,
            SqeOpts::default(),
            21,
        )
        .unwrap();
        ring.submit_and_wait(1).unwrap();
        assert_eq!(ring.wait_cqe().unwrap().bytes().unwrap(), 4096);

        let mut rbuf = AlignedBuf::zeroed(4096);
        ring.prep_read_opts(
            FdSlot::Fixed(2),
            rbuf.as_mut_ptr(),
            4096,
            0,
            SqeOpts::default(),
            22,
        )
        .unwrap();
        ring.submit_and_wait(1).unwrap();
        assert_eq!(ring.wait_cqe().unwrap().bytes().unwrap(), 4096);
        assert_eq!(&rbuf[..17], b"fixed-file slot 2");
        assert_eq!(ring.stats().fixed_file_ops, 2);

        // Clearing the slot makes further ops on it fail (EBADF).
        ring.update_registered_file(2, -1).unwrap();
        ring.prep_read_opts(
            FdSlot::Fixed(2),
            rbuf.as_mut_ptr(),
            4096,
            0,
            SqeOpts::default(),
            23,
        )
        .unwrap();
        ring.submit_and_wait(1).unwrap();
        assert!(ring.wait_cqe().unwrap().bytes().is_err());
        drop(f);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn fixed_file_op_without_table_rejected() {
        if !IoUring::is_supported() {
            eprintln!("skipping: io_uring unavailable on this kernel");
            return;
        }
        let mut ring = IoUring::new(4).unwrap();
        let buf = AlignedBuf::zeroed(4096);
        assert!(ring
            .prep_write_opts(
                FdSlot::Fixed(0),
                buf.as_ptr(),
                4096,
                0,
                SqeOpts::default(),
                1
            )
            .is_err());
        assert!(ring.update_registered_file(0, 1).is_err());
    }

    #[test]
    fn linked_write_fsync_one_submission() {
        if !IoUring::is_supported() {
            eprintln!("skipping: io_uring unavailable on this kernel");
            return;
        }
        let mut ring = IoUring::new(8).unwrap();
        let (path, f) = tmpfile("linked");
        let mut buf = AlignedBuf::zeroed(4096);
        buf.write_at(0, b"ordered");
        ring.prep_write(f.as_raw_fd(), buf.as_ptr(), 4096, 0, 31).unwrap();
        // DRAIN orders the fsync after the write inside the kernel; no
        // userspace completion round-trip between them.
        ring.prep_fsync_opts(
            FdSlot::Raw(f.as_raw_fd()),
            SqeOpts {
                drain: true,
                ..SqeOpts::default()
            },
            32,
        )
        .unwrap();
        let submitted = ring.submit_and_wait(2).unwrap();
        assert_eq!(submitted, 2);
        let mut got = [ring.wait_cqe().unwrap(), ring.wait_cqe().unwrap()];
        got.sort_by_key(|c| c.user_data);
        assert_eq!(got[0].user_data, 31);
        assert_eq!(got[0].bytes().unwrap(), 4096);
        assert_eq!(got[1].user_data, 32);
        assert_eq!(got[1].result, 0);
        let st = ring.stats();
        assert_eq!(st.submit_calls, 1);
        assert_eq!(st.sqes_submitted, 2);
        assert_eq!(st.linked_fsyncs, 1);
        drop(f);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn sqpoll_request_degrades_or_works() {
        if !IoUring::is_supported() {
            eprintln!("skipping: io_uring unavailable on this kernel");
            return;
        }
        let feats = UringFeatures {
            sqpoll: true,
            sqpoll_idle_ms: 20,
            ..UringFeatures::none()
        };
        // Must never hard-fail: either a live SQPOLL ring or the plain
        // fallback.
        let mut ring = IoUring::new_with(8, &feats).unwrap();
        if !ring.sqpoll_active() {
            eprintln!("note: SQPOLL not granted on this kernel, fell back to plain ring");
        }
        let (path, f) = tmpfile("sqpoll");
        let mut buf = AlignedBuf::zeroed(4096);
        buf.write_at(0, b"sqpoll path");
        ring.prep_write(f.as_raw_fd(), buf.as_ptr(), 4096, 0, 41).unwrap();
        ring.submit_and_wait(1).unwrap();
        let c = ring.wait_cqe().unwrap();
        assert_eq!(c.user_data, 41);
        assert_eq!(c.bytes().unwrap(), 4096);
        assert_eq!(ring.stats().sqes_submitted, 1);
        drop(f);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn probe_features_is_subset_of_request() {
        let granted = probe_features(UringFeatures::all());
        let req = UringFeatures::all();
        assert!(!granted.fixed_files || req.fixed_files);
        assert!(!granted.sqpoll || req.sqpoll);
        assert!(!granted.linked_fsync || req.linked_fsync);
        assert!(!granted.shared_ring || req.shared_ring);
        // Requesting nothing grants nothing.
        assert!(!probe_features(UringFeatures::none()).any());
    }

    #[test]
    fn ring_stats_merge_accumulates() {
        let mut a = RingStats {
            submit_calls: 1,
            sqes_submitted: 4,
            sqpoll_wakeups: 2,
            fixed_file_ops: 3,
            linked_fsyncs: 1,
        };
        let b = a;
        a.merge(&b);
        assert_eq!(a.submit_calls, 2);
        assert_eq!(a.sqes_submitted, 8);
        assert_eq!(a.sqpoll_wakeups, 4);
        assert_eq!(a.fixed_file_ops, 6);
        assert_eq!(a.linked_fsyncs, 2);
    }

    #[test]
    fn reap_available_drains() {
        if !IoUring::is_supported() {
            eprintln!("skipping: io_uring unavailable on this kernel");
            return;
        }
        let mut ring = IoUring::new(16).unwrap();
        for i in 0..10 {
            ring.prep_nop(i).unwrap();
        }
        ring.submit_and_wait(10).unwrap();
        let comps = ring.reap_available(100);
        assert_eq!(comps.len(), 10);
        assert!(ring.peek_cqe().is_none());
    }
}
