//! The figure-regeneration harness.
//!
//! No criterion in the offline crate set, so this is a purpose-built
//! harness: each `rust/benches/figNN_*.rs` binary regenerates one (or a
//! pair of) paper figure(s), printing the measured series next to the
//! paper's expectation so the *shape* comparison is immediate, and
//! appending machine-readable rows to `bench_results/` as JSON.
//!
//! Conventions:
//! * simulated substrate (Polaris calibration) for the paper figures —
//!   deterministic, repetition-free;
//! * `uring_microbench` additionally exercises the real kernel io_uring
//!   on local ext4.

use std::path::PathBuf;

use crate::util::json::Json;

/// CI smoke mode: `CKPTIO_BENCH_SMOKE=1` makes every bench take its
/// fast path — problem sizes shrink to a single small iteration and
/// shape checks are reported but never fail the process (tiny inputs
/// are outside the calibrated regime; the smoke job validates that the
/// harness runs end-to-end and emits JSON, not the figure shapes).
pub fn smoke_mode() -> bool {
    std::env::var("CKPTIO_BENCH_SMOKE").is_ok_and(|v| !v.is_empty() && v != "0")
}

/// Pick `full` normally, `small` under [`smoke_mode`] — the one-line
/// knob benches use to shrink rank counts and payload sizes.
pub fn smoke_or<T>(full: T, small: T) -> T {
    if smoke_mode() {
        small
    } else {
        full
    }
}

/// A printed + persisted result table for one figure.
pub struct FigureTable {
    figure: String,
    title: String,
    columns: Vec<String>,
    rows: Vec<Vec<String>>,
    json_rows: Vec<Json>,
    expectations: Vec<String>,
    checks: Vec<(String, bool)>,
}

impl FigureTable {
    pub fn new(figure: &str, title: &str, columns: &[&str]) -> Self {
        println!("\n=== {figure}: {title} ===");
        Self {
            figure: figure.to_string(),
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            json_rows: Vec::new(),
            expectations: Vec::new(),
            checks: Vec::new(),
        }
    }

    /// Add one data row (already formatted) plus its raw JSON form.
    pub fn row(&mut self, cells: Vec<String>, raw: Json) {
        assert_eq!(cells.len(), self.columns.len());
        self.rows.push(cells);
        self.json_rows.push(raw);
    }

    /// Note what the paper reports for this figure.
    pub fn expect(&mut self, text: &str) {
        self.expectations.push(text.to_string());
    }

    /// Record a pass/fail shape check (ordering, ratio band, crossover).
    pub fn check(&mut self, name: &str, ok: bool) {
        self.checks.push((name.to_string(), ok));
    }

    /// Print the table + checks; write JSON; return the number of failed
    /// checks.
    pub fn finish(self) -> usize {
        // Column widths.
        let mut w: Vec<usize> = self.columns.iter().map(|c| c.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                w[i] = w[i].max(cell.len());
            }
        }
        let header: Vec<String> = self
            .columns
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:>width$}", c, width = w[i]))
            .collect();
        println!("{}", header.join("  "));
        for row in &self.rows {
            let line: Vec<String> = row
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>width$}", c, width = w[i]))
                .collect();
            println!("{}", line.join("  "));
        }
        for e in &self.expectations {
            println!("paper: {e}");
        }
        let mut failed = 0;
        for (name, ok) in &self.checks {
            println!(
                "shape-check [{}] {}",
                if *ok { "PASS" } else { "FAIL" },
                name
            );
            failed += usize::from(!ok);
        }

        // Persist machine-readable output.
        let dir = PathBuf::from("bench_results");
        let _ = std::fs::create_dir_all(&dir);
        let mut doc = Json::obj();
        doc.set("figure", self.figure.as_str())
            .set("title", self.title.as_str())
            .set("rows", Json::Arr(self.json_rows))
            .set(
                "checks",
                Json::Arr(
                    self.checks
                        .iter()
                        .map(|(n, ok)| {
                            let mut o = Json::obj();
                            o.set("name", n.as_str()).set("pass", *ok);
                            o
                        })
                        .collect(),
                ),
            );
        let path = dir.join(format!("{}.json", self.figure.replace(['/', ' '], "_")));
        let _ = std::fs::write(path, doc.to_pretty());
        failed
    }
}

/// Exit the bench binary nonzero if any shape checks failed. Under
/// [`smoke_mode`] failures are reported but do not fail the process
/// (smoke inputs are outside the calibrated regime).
pub fn conclude(failed: usize) {
    if failed > 0 {
        if smoke_mode() {
            eprintln!("{failed} shape check(s) FAILED (ignored: CKPTIO_BENCH_SMOKE)");
            return;
        }
        eprintln!("{failed} shape check(s) FAILED");
        std::process::exit(1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_roundtrip() {
        let mut t = FigureTable::new("test-fig", "unit test", &["a", "b"]);
        let mut j = Json::obj();
        j.set("a", 1u64);
        t.row(vec!["1".into(), "2".into()], j);
        t.expect("nothing");
        t.check("always", true);
        assert_eq!(t.finish(), 0);
        let _ = std::fs::remove_file("bench_results/test-fig.json");
    }

    #[test]
    fn smoke_helpers() {
        // The env var is not set under `cargo test`.
        if std::env::var("CKPTIO_BENCH_SMOKE").is_err() {
            assert!(!smoke_mode());
            assert_eq!(smoke_or(8, 2), 8);
        }
    }

    #[test]
    fn failed_checks_counted() {
        let mut t = FigureTable::new("test-fig2", "unit test", &["x"]);
        t.check("bad", false);
        t.check("good", true);
        assert_eq!(t.finish(), 1);
        let _ = std::fs::remove_file("bench_results/test-fig2.json");
    }
}
