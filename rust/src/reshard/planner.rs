//! The extent read planner: target slices → coalesced read plans.
//!
//! Resharding a checkpoint on restore scatters every target rank's
//! state across many source shards. Read naively — one read per
//! (target slice ∩ source extent) fragment — the restore degenerates
//! into the small-I/O regime the paper shows halving throughput. The
//! planner merges adjacent and near-adjacent fragments *per source
//! file* into large coalesced reads, over-reading at most `gap_fill`
//! bytes between any two payload fragments (the read-side mirror of
//! the write-side aggregation knobs; `ablation_coalescing` measures
//! the write side, `fig22_elastic_restore` this one).
//!
//! Output is a [`RankPlan`] per target rank — executable on the real
//! executors and on [`crate::simpfs::exec::SimExecutor`] alike — plus
//! the scatter map that places each fragment's bytes into the target
//! rank's tensor slices after the reads land.

use std::collections::BTreeMap;

use crate::plan::{FileSpec, PlanOp, RankPlan};
use crate::reshard::index::{DpMode, ShardIndex};
use crate::util::align::{align_down, align_up, DIRECT_IO_ALIGN};
use crate::util::bytes::MIB;
use crate::workload::parallelism::{even_split, Parallelism};

/// One contiguous slice of a logical tensor a target rank holds.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSlice {
    pub tensor: String,
    /// Byte offset within the logical tensor.
    pub off: u64,
    pub len: u64,
}

/// Partition an inventory (canonical name order — see
/// [`ShardIndex::inventory`]) across the ranks of `target`:
///
/// * tensors are assigned to pipeline stages in contiguous blocks
///   (remainder to the early stages, mirroring
///   [`Parallelism::stage_layers`]);
/// * [`DpMode::Replicated`] tensors split exactly across the stage's
///   tp ranks — every dp replica needs the same slice;
/// * [`DpMode::Partitioned`] tensors split across the stage's whole
///   (tp × dp) grid (dp-major, ZeRO-style) — or tp only under
///   `zero_stage == 0`.
///
/// Zero-length slices are omitted, so small tensors on large grids
/// simply land on the early ranks.
pub fn target_slices(
    inventory: &[(String, u64, DpMode)],
    target: Parallelism,
) -> Vec<Vec<TensorSlice>> {
    let n = inventory.len() as u64;
    // stage_of[i]: the pipeline stage owning inventory entry i.
    let mut stage_of = vec![0usize; inventory.len()];
    for stage in 0..target.pp {
        let (start, len) = even_split(n, target.pp as u64, stage as u64);
        for s in stage_of
            .iter_mut()
            .skip(start as usize)
            .take(len as usize)
        {
            *s = stage;
        }
    }
    let zero = target.zero_stage >= 1;
    (0..target.world())
        .map(|rank| {
            let c = target.coord(rank);
            let mut out = Vec::new();
            for (i, (name, len, mode)) in inventory.iter().enumerate() {
                if stage_of[i] != c.pp {
                    continue;
                }
                let (off, l) = match mode {
                    DpMode::Replicated => even_split(*len, target.tp as u64, c.tp as u64),
                    DpMode::Partitioned => {
                        let dp_parts = if zero { target.dp } else { 1 };
                        let part = if zero { c.dp * target.tp + c.tp } else { c.tp };
                        even_split(*len, (target.tp * dp_parts) as u64, part as u64)
                    }
                };
                if l > 0 {
                    out.push(TensorSlice {
                        tensor: name.clone(),
                        off,
                        len: l,
                    });
                }
            }
            out
        })
        .collect()
}

/// One scatter step: copy `len` bytes of the read staging buffer into
/// a target slice.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Scatter {
    /// Offset in the rank's read staging buffer.
    pub staging_off: u64,
    /// Index into the rank's slice list.
    pub slice: usize,
    /// Offset within that slice.
    pub slice_off: u64,
    pub len: u64,
}

/// One coalesced read: `(file id, file offset, length)`.
pub type ReadExtent = (usize, u64, u64);

/// The compiled read plan of one target rank.
#[derive(Debug, Clone)]
pub struct RankReadPlan {
    pub rank: usize,
    /// Executable plan: opens + coalesced reads + a staging-copy op
    /// modeling the scatter memcpy.
    pub plan: RankPlan,
    /// The slices this rank restores, in scatter order.
    pub slices: Vec<TensorSlice>,
    pub scatter: Vec<Scatter>,
    /// The coalesced reads, per plan file id.
    pub read_extents: Vec<ReadExtent>,
    /// The payload fragments (file id, file offset, len) before
    /// coalescing — what a naive per-shard reader would issue.
    pub frag_extents: Vec<ReadExtent>,
    /// Bytes the emitted reads move (payload + gap fill + O_DIRECT
    /// alignment expansion).
    pub read_bytes: u64,
    /// Logical payload bytes of the slices.
    pub payload_bytes: u64,
}

impl RankReadPlan {
    /// Check the planner's contract: fragments are disjoint, every
    /// fragment lies inside exactly one coalesced read, reads start and
    /// end on fragment boundaries, internal gaps never exceed
    /// `gap_fill`, and no byte is read twice.
    pub fn validate(&self, gap_fill: u64) -> Result<(), String> {
        let mut by_file: BTreeMap<usize, Vec<(u64, u64)>> = BTreeMap::new();
        for &(f, off, len) in &self.frag_extents {
            by_file.entry(f).or_default().push((off, off + len));
        }
        for frags in by_file.values_mut() {
            frags.sort_unstable();
            for w in frags.windows(2) {
                if w[1].0 < w[0].1 {
                    return Err(format!("fragments overlap at {}..{}", w[1].0, w[0].1));
                }
            }
        }
        let mut reads: BTreeMap<usize, Vec<(u64, u64)>> = BTreeMap::new();
        for &(f, off, len) in &self.read_extents {
            reads.entry(f).or_default().push((off, off + len));
        }
        for (f, rs) in reads.iter_mut() {
            rs.sort_unstable();
            for w in rs.windows(2) {
                if w[1].0 < w[0].1 {
                    return Err(format!("file {f}: double-read at {}..{}", w[1].0, w[0].1));
                }
            }
        }
        for (f, frags) in &by_file {
            let rs = reads.get(f).ok_or_else(|| format!("file {f}: no reads"))?;
            // Each read must decompose into its fragments with bounded
            // internal gaps and fragment-aligned boundaries.
            for &(rlo, rhi) in rs {
                let inside: Vec<(u64, u64)> = frags
                    .iter()
                    .copied()
                    .filter(|&(lo, hi)| lo >= rlo && hi <= rhi)
                    .collect();
                if inside.is_empty() {
                    return Err(format!("file {f}: read {rlo}..{rhi} covers no fragment"));
                }
                if inside[0].0 != rlo || inside[inside.len() - 1].1 != rhi {
                    return Err(format!(
                        "file {f}: read {rlo}..{rhi} not fragment-bounded"
                    ));
                }
                for w in inside.windows(2) {
                    if w[1].0 - w[0].1 > gap_fill {
                        return Err(format!(
                            "file {f}: gap {} exceeds gap_fill {gap_fill}",
                            w[1].0 - w[0].1
                        ));
                    }
                }
            }
            // And every fragment must lie inside some read.
            for &(lo, hi) in frags {
                if !rs.iter().any(|&(rlo, rhi)| lo >= rlo && hi <= rhi) {
                    return Err(format!("file {f}: fragment {lo}..{hi} unread"));
                }
            }
        }
        let frag_total: u64 = self.frag_extents.iter().map(|&(_, _, l)| l).sum();
        let slice_total: u64 = self.slices.iter().map(|s| s.len).sum();
        if frag_total != slice_total {
            return Err(format!(
                "fragments cover {frag_total} bytes but slices need {slice_total}"
            ));
        }
        Ok(())
    }

    /// Coalesced read count (the naive count is
    /// `frag_extents.len()`).
    pub fn reads(&self) -> usize {
        self.read_extents.len()
    }
}

/// The coalescing read planner (knobs documented in
/// `rust/configs/polaris.toml` under `[reshard]`).
#[derive(Debug, Clone)]
pub struct ReadPlanner {
    /// Merge reads across payload gaps up to this many bytes — the
    /// over-read spent to avoid another round trip. 0 still merges
    /// exactly-adjacent fragments.
    pub gap_fill: u64,
    /// Upper bound on one coalesced read (also the chunking size of
    /// emitted `Read` ops).
    pub max_read: u64,
    pub queue_depth: u32,
    /// `false`: one read per fragment (the naive per-shard baseline the
    /// bench compares against).
    pub coalesce: bool,
    /// Optional tier prefix for the plan's file paths (e.g.
    /// [`crate::tier::LOCAL_TIER_PREFIX`] to read from the burst
    /// buffer on the simulated substrate).
    pub tier_prefix: Option<String>,
    /// Serve replicated fragments from the least-loaded source copy
    /// (by bytes already planned against each source file across the
    /// whole topology) instead of always the primary's — tp-replicated
    /// tensors otherwise make tp rank 0's file a restore-storm hotspot.
    pub balance_replicas: bool,
}

impl Default for ReadPlanner {
    fn default() -> Self {
        Self {
            gap_fill: MIB,
            max_read: 64 * MIB,
            queue_depth: 32,
            coalesce: true,
            tier_prefix: None,
            balance_replicas: true,
        }
    }
}

impl ReadPlanner {
    /// The naive per-shard baseline: every fragment is its own read,
    /// always from the primary copy.
    pub fn naive() -> Self {
        Self {
            coalesce: false,
            balance_replicas: false,
            ..Default::default()
        }
    }

    pub fn with_gap_fill(mut self, bytes: u64) -> Self {
        self.gap_fill = bytes;
        self
    }

    pub fn with_max_read(mut self, bytes: u64) -> Self {
        self.max_read = bytes.max(1);
        self
    }

    pub fn with_queue_depth(mut self, qd: u32) -> Self {
        assert!(qd >= 1);
        self.queue_depth = qd;
        self
    }

    /// Prefix every plan file path with a tier prefix.
    pub fn on_tier(mut self, prefix: impl Into<String>) -> Self {
        self.tier_prefix = Some(prefix.into());
        self
    }

    /// Toggle least-loaded replica-copy selection.
    pub fn with_balance_replicas(mut self, on: bool) -> Self {
        self.balance_replicas = on;
        self
    }

    /// Read the `[reshard]` knobs out of a site config (e.g.
    /// `rust/configs/polaris.toml`); unspecified keys keep the
    /// defaults.
    pub fn from_toml(text: &str) -> Result<Self, String> {
        use crate::util::bytes::parse_bytes;
        use crate::util::toml::TomlDoc;
        let doc = TomlDoc::parse(text)?;
        let mut p = Self::default();
        if let Some(v) = doc.get_str("reshard.gap_fill") {
            p.gap_fill = parse_bytes(v)?;
        } else if let Some(v) = doc.get_int("reshard.gap_fill") {
            p.gap_fill = v.max(0) as u64;
        }
        if let Some(v) = doc.get_str("reshard.max_read") {
            p.max_read = parse_bytes(v)?.max(1);
        } else if let Some(v) = doc.get_int("reshard.max_read") {
            p.max_read = (v.max(1)) as u64;
        }
        if let Some(v) = doc.get_int("reshard.queue_depth") {
            if v >= 1 {
                p.queue_depth = v as u32;
            }
        }
        if let Some(v) = doc.get_bool("reshard.balance_replicas") {
            p.balance_replicas = v;
        }
        Ok(p)
    }

    /// Compile the read plans of every target rank (`node = rank /
    /// ranks_per_node`, so the simulator shares NICs correctly). The
    /// per-source-file load tally balancing replica-copy choices spans
    /// the whole topology: what rank 0's plan reads from a file counts
    /// against that file when rank 1's plan picks its copies.
    pub fn rank_plans(
        &self,
        index: &ShardIndex,
        target: Parallelism,
        ranks_per_node: usize,
    ) -> Vec<RankReadPlan> {
        let inventory = index.inventory();
        let slices = target_slices(&inventory, target);
        let mut load: BTreeMap<String, u64> = BTreeMap::new();
        slices
            .into_iter()
            .enumerate()
            .map(|(rank, s)| {
                self.plan_rank_loaded(index, rank, rank / ranks_per_node.max(1), s, &mut load)
            })
            .collect()
    }

    /// Compile one target rank's plan from its slice list (fresh load
    /// tally — copy balancing sees only this rank's reads).
    pub fn plan_rank(
        &self,
        index: &ShardIndex,
        rank: usize,
        node: usize,
        slices: Vec<TensorSlice>,
    ) -> RankReadPlan {
        let mut load = BTreeMap::new();
        self.plan_rank_loaded(index, rank, node, slices, &mut load)
    }

    /// [`Self::plan_rank`] against a caller-held bytes-per-source-file
    /// tally, so copy balancing can span many ranks (or many storm
    /// readers).
    pub fn plan_rank_loaded(
        &self,
        index: &ShardIndex,
        rank: usize,
        node: usize,
        slices: Vec<TensorSlice>,
        load: &mut BTreeMap<String, u64>,
    ) -> RankReadPlan {
        struct Fragment {
            file: usize,
            file_off: u64,
            len: u64,
            slice: usize,
            slice_off: u64,
        }
        let mut plan = RankPlan::new(rank, node);
        let mut file_ids: BTreeMap<String, usize> = BTreeMap::new();
        let mut fragments: Vec<Fragment> = Vec::new();
        for (si, s) in slices.iter().enumerate() {
            let t = match index.tensors.get(&s.tensor) {
                Some(t) => t,
                None => continue, // validated away by RankReadPlan::validate
            };
            let (lo, hi) = (s.off, s.off + s.len);
            for p in &t.extents {
                let flo = p.logical_off.max(lo);
                let fhi = p.logical_end().min(hi);
                if flo >= fhi {
                    continue;
                }
                // Pick the serving copy: the primary, unless balancing
                // is on and an alternate copy's source file carries
                // less planned load (ties break on path for
                // determinism).
                let e = if self.balance_replicas && !t.alts.is_empty() {
                    t.copies_of(p)
                        .into_iter()
                        .min_by_key(|c| (load.get(&c.path).copied().unwrap_or(0), &c.path))
                        .unwrap()
                } else {
                    p
                };
                *load.entry(e.path.clone()).or_insert(0) += fhi - flo;
                let file = match file_ids.get(&e.path) {
                    Some(&f) => f,
                    None => {
                        let f = plan.add_file(FileSpec {
                            path: crate::tier::tier_path(
                                self.tier_prefix.as_deref().unwrap_or(""),
                                &e.path,
                            ),
                            // Reads are alignment-expanded below, so
                            // they stay O_DIRECT like every other
                            // restore path (§3.4).
                            direct: true,
                            size_hint: 0,
                            creates: false,
                        });
                        file_ids.insert(e.path.clone(), f);
                        f
                    }
                };
                fragments.push(Fragment {
                    file,
                    file_off: e.file_off + (flo - e.logical_off),
                    len: fhi - flo,
                    slice: si,
                    slice_off: flo - s.off,
                });
            }
        }

        // Coalesce per file: fragments sorted by offset merge while the
        // inter-fragment gap stays within gap_fill and the merged read
        // within max_read.
        let mut order: Vec<usize> = (0..fragments.len()).collect();
        order.sort_by_key(|&i| (fragments[i].file, fragments[i].file_off));
        let mut read_extents: Vec<ReadExtent> = Vec::new();
        // Fragment index → index of the read covering it.
        let mut frag_read: Vec<usize> = vec![0; fragments.len()];
        for &i in &order {
            let f = &fragments[i];
            let merged = self.coalesce
                && read_extents.last().is_some_and(|&(rf, roff, rlen)| {
                    rf == f.file
                        && f.file_off >= roff + rlen
                        && f.file_off - (roff + rlen) <= self.gap_fill
                        && (f.file_off + f.len) - roff <= self.max_read
                });
            if merged {
                let ri = read_extents.len() - 1;
                frag_read[i] = ri;
                let last = &mut read_extents[ri];
                last.2 = f.file_off + f.len - last.1;
            } else {
                frag_read[i] = read_extents.len();
                read_extents.push((f.file, f.file_off, f.len));
            }
        }
        // O_DIRECT alignment: each read expands to DIRECT_IO_ALIGN
        // boundaries (≤ align−1 extra bytes per side) — the explicit
        // per-buffer alignment real reshard readers pay (§3.6) — so the
        // plans run under O_DIRECT on the real executor and as direct
        // reads in the simulator. Two aligned reads may overlap inside
        // a shared boundary block; the logical extents stay disjoint.
        let aligned: Vec<ReadExtent> = read_extents
            .iter()
            .map(|&(f, off, len)| {
                let a0 = align_down(off, DIRECT_IO_ALIGN);
                let a1 = align_up(off + len, DIRECT_IO_ALIGN);
                (f, a0, a1 - a0)
            })
            .collect();
        // Staging: aligned reads laid out back to back (offsets stay
        // block-aligned because every aligned length is).
        let mut read_staging = Vec::with_capacity(aligned.len());
        let mut cursor = 0u64;
        for &(_, _, len) in &aligned {
            read_staging.push(cursor);
            cursor += len;
        }
        let scatter: Vec<Scatter> = order
            .iter()
            .map(|&i| {
                let f = &fragments[i];
                let ri = frag_read[i];
                Scatter {
                    staging_off: read_staging[ri] + (f.file_off - aligned[ri].1),
                    slice: f.slice,
                    slice_off: f.slice_off,
                    len: f.len,
                }
            })
            .collect();

        plan.push(PlanOp::QueueDepth {
            qd: self.queue_depth,
        });
        for f in 0..plan.files.len() {
            plan.push(PlanOp::Open { file: f });
        }
        let chunk = align_up(self.max_read.max(DIRECT_IO_ALIGN), DIRECT_IO_ALIGN);
        for (ri, &(file, off, len)) in aligned.iter().enumerate() {
            // Chunk at (aligned) max_read so no single op outgrows the
            // transfer granularity (merging already respects the cap;
            // naive fragments of huge tensors may not).
            crate::engines::push_chunked(
                &mut plan,
                false,
                file,
                off,
                read_staging[ri],
                len,
                chunk,
            );
        }
        plan.push(PlanOp::Drain);
        let payload_bytes: u64 = fragments.iter().map(|f| f.len).sum();
        if payload_bytes > 0 {
            // The scatter pass out of the read staging into the target
            // tensors — a bulk memcpy, modeled as such.
            plan.push(PlanOp::StagingCopy {
                bytes: payload_bytes,
            });
        }
        let read_bytes: u64 = aligned.iter().map(|&(_, _, l)| l).sum();
        RankReadPlan {
            rank,
            plan,
            slices,
            scatter,
            frag_extents: fragments
                .iter()
                .map(|f| (f.file, f.file_off, f.len))
                .collect(),
            read_extents,
            read_bytes,
            payload_bytes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ckpt::Aggregation;
    use crate::workload::ModelSpec;

    fn inventory() -> Vec<(String, u64, DpMode)> {
        vec![
            ("layers.0.w".into(), 1000, DpMode::Replicated),
            ("layers.1.w".into(), 999, DpMode::Replicated),
            ("optim.state".into(), 4000, DpMode::Partitioned),
        ]
    }

    #[test]
    fn slices_partition_exactly() {
        for &(tp, pp, dp) in &[(1, 1, 1), (2, 1, 1), (2, 2, 2), (3, 2, 1), (1, 3, 2)] {
            let target = Parallelism::new(tp, pp, dp);
            let slices = target_slices(&inventory(), target);
            assert_eq!(slices.len(), target.world());
            // Replicated tensors: each dp replica covers the tensor
            // once → total coverage = dp × len. Partitioned: once.
            let mut cover: BTreeMap<String, u64> = BTreeMap::new();
            for rank in &slices {
                for s in rank {
                    *cover.entry(s.tensor.clone()).or_insert(0) += s.len;
                }
            }
            for (name, len, mode) in inventory() {
                let mult = match mode {
                    DpMode::Replicated => dp as u64,
                    DpMode::Partitioned => 1,
                };
                assert_eq!(
                    cover.get(&name).copied().unwrap_or(0),
                    len * mult,
                    "{name} under ({tp},{pp},{dp})"
                );
            }
        }
    }

    #[test]
    fn replicated_slices_agree_across_dp() {
        let target = Parallelism::new(2, 1, 3);
        let slices = target_slices(&inventory(), target);
        for tp in 0..2 {
            let r0 = &slices[target.rank_of(crate::workload::parallelism::RankCoord {
                tp,
                pp: 0,
                dp: 0,
            })];
            for dp in 1..3 {
                let r = &slices[target.rank_of(crate::workload::parallelism::RankCoord {
                    tp,
                    pp: 0,
                    dp,
                })];
                let a: Vec<_> = r0.iter().filter(|s| s.tensor != "optim.state").collect();
                let b: Vec<_> = r.iter().filter(|s| s.tensor != "optim.state").collect();
                assert_eq!(a, b, "dp replicas need identical model slices");
            }
        }
    }

    #[test]
    fn planner_covers_and_coalesces() {
        let spec = ModelSpec::tiny_100m();
        let src = Parallelism::new(4, 1, 1);
        let idx = ShardIndex::from_layout(&spec, src, Aggregation::FilePerProcess).unwrap();
        let target = Parallelism::new(1, 1, 1);
        let coalesced = ReadPlanner::default().with_gap_fill(64 * 1024);
        let naive = ReadPlanner::naive();
        let cps = coalesced.rank_plans(&idx, target, 4);
        let nps = naive.rank_plans(&idx, target, 4);
        assert_eq!(cps.len(), 1);
        for rp in cps.iter().chain(nps.iter()) {
            rp.plan.validate().unwrap();
            rp.validate(if rp.reads() == rp.frag_extents.len() {
                0
            } else {
                64 * 1024
            })
            .unwrap();
            assert_eq!(rp.payload_bytes, idx.payload_bytes());
        }
        // Fewer and strictly larger reads than the naive baseline.
        assert!(cps[0].reads() < nps[0].reads());
        assert_eq!(nps[0].reads(), nps[0].frag_extents.len());
        let mean = |rp: &RankReadPlan| rp.read_bytes as f64 / rp.reads() as f64;
        assert!(mean(&cps[0]) > mean(&nps[0]));
        // Gap fill over-reads, but never payload-free reads.
        assert!(cps[0].read_bytes >= cps[0].payload_bytes);
    }

    #[test]
    fn gap_fill_monotone_in_read_count() {
        let spec = ModelSpec::tiny_100m();
        let src = Parallelism::new(2, 2, 1);
        let idx = ShardIndex::from_layout(&spec, src, Aggregation::FilePerProcess).unwrap();
        let target = Parallelism::new(1, 1, 2);
        let mut prev = usize::MAX;
        for gap in [0u64, 4096, 65536, MIB] {
            let rps = ReadPlanner::default()
                .with_gap_fill(gap)
                .rank_plans(&idx, target, 4);
            let reads: usize = rps.iter().map(|r| r.reads()).sum();
            assert!(reads <= prev, "gap {gap}: {reads} > {prev}");
            prev = reads;
            for rp in &rps {
                rp.validate(gap).unwrap();
            }
        }
    }

    #[test]
    fn balanced_planner_spreads_replicated_tensors() {
        // A tp=4 source: layer norms etc. are tp-replicated, so each
        // has one primary copy (tp rank 0's file) and three alternates.
        let spec = ModelSpec::tiny_100m();
        let src = Parallelism::new(4, 1, 1);
        let idx = ShardIndex::from_layout(&spec, src, Aggregation::FilePerProcess).unwrap();
        let replicated: Vec<&str> = idx
            .tensors
            .values()
            .filter(|t| !t.alts.is_empty())
            .map(|t| t.name.as_str())
            .collect();
        assert!(!replicated.is_empty());
        let target = Parallelism::new(1, 1, 1);
        let bytes_per_file = |rps: &[RankReadPlan]| -> BTreeMap<String, u64> {
            let mut by: BTreeMap<String, u64> = BTreeMap::new();
            for rp in rps {
                for &(f, _, len) in &rp.frag_extents {
                    *by.entry(rp.plan.files[f].path.clone()).or_insert(0) += len;
                }
            }
            by
        };
        let pinned = ReadPlanner::default()
            .with_balance_replicas(false)
            .rank_plans(&idx, target, 4);
        let balanced = ReadPlanner::default().rank_plans(&idx, target, 4);
        for rps in [&pinned, &balanced] {
            for rp in rps.iter() {
                rp.plan.validate().unwrap();
                rp.validate(ReadPlanner::default().gap_fill).unwrap();
            }
        }
        // Same total payload either way; the balanced plan serves it
        // from a flatter per-file distribution (smaller max file load).
        let p = bytes_per_file(&pinned);
        let b = bytes_per_file(&balanced);
        assert_eq!(p.values().sum::<u64>(), b.values().sum::<u64>());
        let max = |m: &BTreeMap<String, u64>| m.values().copied().max().unwrap_or(0);
        assert!(
            max(&b) < max(&p),
            "balanced max file load {} !< pinned {}",
            max(&b),
            max(&p)
        );
    }

    #[test]
    fn from_toml_reads_knobs() {
        let p = ReadPlanner::from_toml(
            "[reshard]\ngap_fill = \"2M\"\nqueue_depth = 8\nbalance_replicas = false\n",
        )
        .unwrap();
        assert_eq!(p.gap_fill, 2 * MIB);
        assert_eq!(p.queue_depth, 8);
        assert!(!p.balance_replicas);
        assert_eq!(p.max_read, 64 * MIB); // default held
        let d = ReadPlanner::from_toml("").unwrap();
        assert_eq!(d.gap_fill, ReadPlanner::default().gap_fill);
    }
}
