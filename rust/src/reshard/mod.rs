//! `reshard` — elastic restore across parallelism topologies.
//!
//! A checkpoint saved at one parallelism configuration (tp₁, pp₁, dp₁)
//! can be restored into any other (tp₂, pp₂, dp₂), bit-identically at
//! the *logical-tensor* level. ByteCheckpoint's headline capability is
//! exactly this: real fleets resume on different node counts after
//! failures and re-scheduling, and a checkpoint pinned to its save-time
//! topology forces either a full re-shard pass through host memory or a
//! restart at the old scale. The catch the paper quantifies is on the
//! read side: a target rank's shard is scattered across many source
//! shards, so naive per-shard reads degenerate into exactly the
//! small-buffer I/O regime that halves throughput (§3.6) — unless the
//! reader coalesces adjacent extents back into large transfers, the
//! read-side mirror of the write-side aggregation strategies.
//!
//! The module splits into three layers, mirroring DataStates-LLM's
//! composable-state-provider argument (the resharding math is
//! independent of the storage tier serving the bytes):
//!
//! * [`index`] — the **global shard index**: every logical tensor
//!   mapped to the `(file, offset, len)` extents holding its source
//!   shards. Built either from a real checkpoint store's manifest
//!   ([`index::ShardIndex::from_store`]) or analytically from a model
//!   spec + parallelism via the same offset planner the engines use
//!   ([`index::ShardIndex::from_layout`] over
//!   [`crate::ckpt::aggregation::plan_offsets`]).
//! * [`planner`] — the **extent read planner**: partitions each logical
//!   tensor across the target topology (dp-replicated model state vs
//!   dp-partitioned ZeRO optimizer state), intersects the target
//!   slices with the source extents, and merges adjacent fragments per
//!   source file into coalesced large reads under a configurable
//!   gap-fill threshold — emitting [`crate::plan::RankPlan`]s that run
//!   unchanged on the real executors and on
//!   [`crate::simpfs::exec::SimExecutor`], where resharded restores
//!   contend on the same OST/NIC/SSD/PCIe servers as everything else.
//! * [`elastic`] — the data path: slice full logical tensors into
//!   per-rank shards ([`elastic::shard_data`]), reassemble them
//!   ([`elastic::assemble_logical`]), and execute a planner-driven
//!   elastic restore against a real store
//!   ([`elastic::elastic_restore`]).
//!
//! [`crate::tier::TierCascade::restore_elastic`] composes this with
//! every tier: device-stage snapshots and buddy replicas reshard in
//! memory, storage tiers go through the extent planner, and the
//! fastest-surviving-copy fallback (device → bb → replica → PFS) still
//! applies. `benches/fig22_elastic_restore.rs` sweeps topology pairs
//! and the gap-fill knob.

pub mod elastic;
pub mod index;
pub mod planner;

pub use elastic::{assemble_logical, elastic_restore, elastic_save, reshard_data, shard_data};
pub use index::{DpMode, LogicalTensor, ShardExtent, ShardIndex};
pub use planner::{RankReadPlan, ReadPlanner, TensorSlice};
