//! The elastic data path: shard, reassemble, save, and restore logical
//! tensors across topologies.
//!
//! Two restore paths share one slicing rule ([`super::planner::target_slices`]):
//!
//! * **in-memory** ([`reshard_data`]) — reassemble the logical tensors
//!   from already-loaded [`RankData`] and re-slice them at the target
//!   topology; used when a faster tier (device HBM, a buddy replica)
//!   already produced the bytes, and as the reference implementation
//!   the property tests compare the planner path against;
//! * **planner-driven** ([`elastic_restore`]) — compile coalesced read
//!   plans over a [`ShardIndex`], execute them against the real store
//!   through a [`crate::exec::real::RealExecutor`], and scatter the
//!   staging bytes into the target ranks' tensor slices.
//!
//! Shard blobs are named `tensor@logical_off`
//! ([`super::index::shard_blob_name`]), so a re-saved resharded
//! checkpoint indexes again with [`ShardIndex::from_store`] — elastic
//! restores compose (A → B → C) without ever materializing the whole
//! model on one rank except where a topology genuinely demands it.

use std::io::{Read, Seek, SeekFrom};
use std::path::Path;

use crate::ckpt::lean::{self, Lean};
use crate::ckpt::store::{CheckpointStore, RankData, SaveReport};
use crate::error::{Error, Result};
use crate::exec::real::{BackendKind, RealExecutor};
use crate::reshard::index::{parse_shard_blob_name, shard_blob_name, DpMode, ShardIndex};
use crate::reshard::planner::ReadPlanner;
use crate::uring::AlignedBuf;
use crate::util::json::Json;
use crate::workload::parallelism::Parallelism;

/// Slice full logical tensors into per-rank [`RankData`] at `par`.
/// Tensors are taken in lexicographic name order (the canonical
/// inventory order — see [`ShardIndex::inventory`]); every rank gets a
/// clone of `lean`. Ranks whose slice set is empty still appear (with
/// no tensors), so the store's rank count matches `par.world()`.
pub fn shard_data(logical: &[(String, Vec<u8>)], par: Parallelism, lean: &Lean) -> Vec<RankData> {
    let mut sorted: Vec<&(String, Vec<u8>)> = logical.iter().collect();
    sorted.sort_by(|a, b| a.0.cmp(&b.0));
    let inventory: Vec<(String, u64, DpMode)> = sorted
        .iter()
        .map(|(n, b)| (n.clone(), b.len() as u64, DpMode::of_name(n)))
        .collect();
    let by_name: std::collections::BTreeMap<&str, &[u8]> = sorted
        .iter()
        .map(|(n, b)| (n.as_str(), b.as_slice()))
        .collect();
    super::planner::target_slices(&inventory, par)
        .into_iter()
        .enumerate()
        .map(|(rank, slices)| {
            let tensors = slices
                .iter()
                .map(|s| {
                    let src = by_name[s.tensor.as_str()];
                    (
                        shard_blob_name(&s.tensor, s.off),
                        src[s.off as usize..(s.off + s.len) as usize].to_vec(),
                    )
                })
                .collect();
            RankData {
                rank,
                tensors,
                lean: lean.clone(),
            }
        })
        .collect()
}

/// Reassemble full logical tensors from sharded rank data. Shard blobs
/// must tile each tensor exactly; dp-replicated duplicates (identical
/// range from several ranks) are accepted and must agree byte-for-byte.
pub fn assemble_logical(data: &[RankData]) -> Result<Vec<(String, Vec<u8>)>> {
    let mut shards: std::collections::BTreeMap<String, Vec<(u64, &[u8])>> =
        std::collections::BTreeMap::new();
    for d in data {
        for (blob, bytes) in &d.tensors {
            let (tensor, off) = parse_shard_blob_name(blob);
            shards
                .entry(tensor.to_string())
                .or_default()
                .push((off, bytes.as_slice()));
        }
    }
    let mut out = Vec::with_capacity(shards.len());
    for (name, mut parts) in shards {
        parts.sort_by_key(|&(off, b)| (off, b.len()));
        let mut bytes = Vec::new();
        for (off, b) in parts {
            if off < bytes.len() as u64 {
                // A dp replica of a range already assembled: verify
                // instead of re-appending.
                let end = off + b.len() as u64;
                if end > bytes.len() as u64
                    || &bytes[off as usize..end as usize] != b
                {
                    return Err(Error::Integrity(format!(
                        "{name}: replica shard at {off} disagrees or misaligns"
                    )));
                }
                continue;
            }
            if off != bytes.len() as u64 {
                return Err(Error::Integrity(format!(
                    "{name}: shard gap at {off} (have {})",
                    bytes.len()
                )));
            }
            bytes.extend_from_slice(b);
        }
        out.push((name, bytes));
    }
    if out.is_empty() {
        return Err(Error::format("assemble: no tensor shards"));
    }
    Ok(out)
}

/// Reshard already-loaded rank data onto `target` in memory —
/// reassembly followed by re-slicing. The lean object of the first
/// source rank rides along to every target rank.
pub fn reshard_data(data: &[RankData], target: Parallelism) -> Result<Vec<RankData>> {
    if data.is_empty() {
        return Err(Error::msg("reshard: no rank data"));
    }
    let logical = assemble_logical(data)?;
    Ok(shard_data(&logical, target, &data[0].lean))
}

/// Save full logical tensors sharded at `par` into a
/// [`CheckpointStore`] under `root`.
pub fn elastic_save(
    root: &Path,
    logical: &[(String, Vec<u8>)],
    par: Parallelism,
    backend: BackendKind,
) -> Result<SaveReport> {
    let data = shard_data(logical, par, &lean::training_state(0, 0.0, "elastic"));
    CheckpointStore::new(root).with_backend(backend).save(&data)
}

/// The first lean blob recorded in a store's sidecar, if any — elastic
/// restore clones it onto every target rank (rank-local training state
/// does not reshard; a resumed run re-derives schedules from the step).
fn store_lean(root: &Path) -> Option<Lean> {
    let text = std::fs::read_to_string(root.join("ckpt.manifest.json")).ok()?;
    let side = Json::parse(&text).ok()?;
    let items = side.get("items").and_then(Json::as_arr)?;
    let it = items
        .iter()
        .find(|it| it.get("kind").and_then(Json::as_str) == Some("lean"))?;
    let path = it.get("path").and_then(Json::as_str)?;
    let offset = it.get("offset").and_then(Json::as_u64)?;
    let len = it.get("len").and_then(Json::as_u64)? as usize;
    let mut f = std::fs::File::open(root.join(path)).ok()?;
    f.seek(SeekFrom::Start(offset)).ok()?;
    let mut buf = vec![0u8; len];
    f.read_exact(&mut buf).ok()?;
    lean::decode(&buf).ok()
}

/// Elastic restore from a real store: compile the planner's coalesced
/// read plans over `index` (alignment-expanded O_DIRECT reads), execute
/// them through the real executor, and scatter the staging bytes into
/// per-target-rank shard blobs. The result re-saves directly (e.g. via
/// [`CheckpointStore::save`]) as a checkpoint *at the target topology*.
pub fn elastic_restore(
    root: &Path,
    index: &ShardIndex,
    target: Parallelism,
    planner: &ReadPlanner,
    backend: BackendKind,
) -> Result<Vec<RankData>> {
    // Node ids are metadata the real executor ignores; simulator-bound
    // plans should come from `ReadPlanner::rank_plans` with the real
    // topology's ranks-per-node (as `Coordinator::restore_elastic`
    // does), not from this data path.
    let rps = planner.rank_plans(index, target, 4);
    for rp in &rps {
        rp.validate(if planner.coalesce { planner.gap_fill } else { 0 })
            .map_err(Error::Integrity)?;
    }
    let plans: Vec<_> = rps.iter().map(|rp| rp.plan.clone()).collect();
    let mut staging: Vec<AlignedBuf> = plans
        .iter()
        .map(|p| AlignedBuf::zeroed((p.staging_bytes() as usize).max(4096)))
        .collect();
    RealExecutor::new(root, backend).run(&plans, &mut staging)?;

    let lean = store_lean(root).unwrap_or_else(Lean::dict);
    let mut out = Vec::with_capacity(rps.len());
    for (rp, stage) in rps.iter().zip(&staging) {
        let mut tensors: Vec<(String, Vec<u8>)> = rp
            .slices
            .iter()
            .map(|s| (shard_blob_name(&s.tensor, s.off), vec![0u8; s.len as usize]))
            .collect();
        for sc in &rp.scatter {
            let src = &stage[sc.staging_off as usize..(sc.staging_off + sc.len) as usize];
            let dst = &mut tensors[sc.slice].1;
            dst[sc.slice_off as usize..(sc.slice_off + sc.len) as usize].copy_from_slice(src);
        }
        out.push(RankData {
            rank: rp.rank,
            tensors,
            lean: lean.clone(),
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Xoshiro256;
    use std::path::PathBuf;

    fn tmp(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "ckptio-elastic-{name}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    /// Logical tensors with 4-byte-multiple sizes (the store's size
    /// model rounds tensor elements to fp32).
    fn logical(seed: u64, n: usize) -> Vec<(String, Vec<u8>)> {
        let mut rng = Xoshiro256::seeded(seed);
        (0..n)
            .map(|i| {
                let len = 4 * (rng.gen_range(64, 6000) as usize);
                let mut b = vec![0u8; len];
                rng.fill_bytes(&mut b);
                let name = if i % 3 == 2 {
                    format!("optim.state.{i:02}")
                } else {
                    format!("layers.{i:02}.weight")
                };
                (name, b)
            })
            .collect()
    }

    #[test]
    fn shard_then_assemble_is_identity() {
        let logical = logical(1, 9);
        for &(tp, pp, dp) in &[(1, 1, 1), (2, 2, 2), (3, 1, 2), (1, 4, 1)] {
            let par = Parallelism::new(tp, pp, dp);
            let data = shard_data(&logical, par, &Lean::dict());
            assert_eq!(data.len(), par.world());
            let mut back = assemble_logical(&data).unwrap();
            back.sort_by(|a, b| a.0.cmp(&b.0));
            let mut want = logical.clone();
            want.sort_by(|a, b| a.0.cmp(&b.0));
            assert_eq!(back, want, "({tp},{pp},{dp})");
        }
    }

    #[test]
    fn reshard_data_roundtrips_across_topologies() {
        let logical = logical(2, 7);
        let a = Parallelism::new(2, 2, 1);
        let b = Parallelism::new(1, 1, 3);
        let at_a = shard_data(&logical, a, &Lean::dict());
        let at_b = reshard_data(&at_a, b).unwrap();
        assert_eq!(at_b.len(), 3);
        let back = reshard_data(&at_b, a).unwrap();
        let mut l2 = assemble_logical(&back).unwrap();
        l2.sort_by(|x, y| x.0.cmp(&y.0));
        let mut want = logical.clone();
        want.sort_by(|x, y| x.0.cmp(&y.0));
        assert_eq!(l2, want);
    }

    #[test]
    fn assemble_rejects_gaps_and_disagreeing_replicas() {
        let mk = |tensors: Vec<(String, Vec<u8>)>| RankData {
            rank: 0,
            tensors,
            lean: Lean::dict(),
        };
        // Gap: shard at 8 with nothing before it.
        let err = assemble_logical(&[mk(vec![("t@8".into(), vec![1, 2])])]).unwrap_err();
        assert!(err.to_string().contains("gap"), "{err}");
        // Disagreeing replica.
        let data = vec![
            mk(vec![("t@0".into(), vec![1, 2, 3, 4])]),
            mk(vec![("t@0".into(), vec![9, 9, 9, 9])]),
        ];
        assert!(assemble_logical(&data).is_err());
        // Agreeing replicas are fine.
        let data = vec![
            mk(vec![("t@0".into(), vec![1, 2, 3, 4])]),
            mk(vec![("t@0".into(), vec![1, 2, 3, 4])]),
        ];
        assert_eq!(assemble_logical(&data).unwrap()[0].1, vec![1, 2, 3, 4]);
    }

    #[test]
    fn save_then_elastic_restore_bit_identical() {
        let root = tmp("rt");
        let logical = logical(3, 8);
        let src = Parallelism::new(2, 1, 2);
        let dst = Parallelism::new(3, 1, 1);
        elastic_save(&root, &logical, src, BackendKind::Posix).unwrap();
        let idx = ShardIndex::from_store(&root).unwrap();
        assert_eq!(idx.source_world, src.world());
        for planner in [ReadPlanner::naive(), ReadPlanner::default().with_gap_fill(4096)] {
            let data =
                elastic_restore(&root, &idx, dst, &planner, BackendKind::Posix).unwrap();
            assert_eq!(data.len(), dst.world());
            let mut back = assemble_logical(&data).unwrap();
            back.sort_by(|a, b| a.0.cmp(&b.0));
            let mut want = logical.clone();
            want.sort_by(|a, b| a.0.cmp(&b.0));
            assert_eq!(back, want, "coalesce={}", planner.coalesce);
        }
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn planner_path_matches_in_memory_reference() {
        let root = tmp("ref");
        let logical = logical(4, 6);
        let src = Parallelism::new(2, 2, 1);
        let dst = Parallelism::new(2, 1, 2);
        let at_src = shard_data(&logical, src, &Lean::dict());
        CheckpointStore::new(&root)
            .with_backend(BackendKind::Posix)
            .save(&at_src)
            .unwrap();
        let idx = ShardIndex::from_store(&root).unwrap();
        let via_files = elastic_restore(
            &root,
            &idx,
            dst,
            &ReadPlanner::default(),
            BackendKind::Posix,
        )
        .unwrap();
        let in_memory = reshard_data(&at_src, dst).unwrap();
        assert_eq!(via_files.len(), in_memory.len());
        for (a, b) in via_files.iter().zip(&in_memory) {
            assert_eq!(a.rank, b.rank);
            assert_eq!(a.tensors, b.tensors);
        }
        std::fs::remove_dir_all(&root).unwrap();
    }
}
