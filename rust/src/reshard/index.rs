//! The global shard index: logical tensor → source shard extents.
//!
//! Elastic restore needs to know, for every logical tensor of the
//! model, which byte ranges of which checkpoint files hold which slice
//! of it. The index normalizes that mapping out of two very different
//! sources:
//!
//! * a **real checkpoint store** ([`ShardIndex::from_store`]): the
//!   `ckpt.manifest.json` sidecar a [`crate::ckpt::store::CheckpointStore`]
//!   writes names every blob with its file, offset and length; sharded
//!   blobs carry their logical offset in the blob name
//!   ([`shard_blob_name`]), whole blobs index as a single extent at
//!   offset 0;
//! * a **derived layout** ([`ShardIndex::from_layout`]): the same
//!   [`crate::ckpt::aggregation::plan_offsets`] placement the engines
//!   compile plans from, over a [`crate::workload::CheckpointLayout`] —
//!   no files needed, which is what the simulator sweeps use.
//!
//! The index's invariant (checked on construction): each logical
//! tensor's extents tile `[0, len)` exactly — no gaps, no overlaps.
//! dp-replicated shards (the same slice stored by several data-parallel
//! ranks) deduplicate to one serving extent.

use std::collections::BTreeMap;
use std::path::Path;

use crate::ckpt::aggregation::{plan_offsets, shared_file_bases, Aggregation, ItemKind};
use crate::error::{Error, Result};
use crate::util::align::DIRECT_IO_ALIGN;
use crate::util::json::Json;
use crate::workload::layout::CheckpointLayout;
use crate::workload::modelspec::ModelSpec;
use crate::workload::parallelism::Parallelism;

/// How a logical tensor relates to the data-parallel dimension.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DpMode {
    /// Model state: every dp replica of a (tp, pp) coordinate holds —
    /// and on restore needs — the same slice.
    Replicated,
    /// ZeRO-partitioned optimizer state: the dp group splits the
    /// tensor, so a topology's whole (tp × dp) grid holds disjoint
    /// slices per pipeline stage.
    Partitioned,
}

impl DpMode {
    /// The naming convention shared by the save and restore sides:
    /// optimizer-state tensors (`optim.*`) partition across dp,
    /// everything else replicates.
    pub fn of_name(name: &str) -> DpMode {
        if name.starts_with("optim.") {
            DpMode::Partitioned
        } else {
            DpMode::Replicated
        }
    }
}

/// Encode a shard blob's name: the logical tensor plus the logical
/// byte offset its bytes start at. [`parse_shard_blob_name`] inverts.
pub fn shard_blob_name(tensor: &str, logical_off: u64) -> String {
    format!("{tensor}@{logical_off}")
}

/// Split a blob name into `(logical tensor, logical offset)`. Names
/// without a parsable `@offset` suffix are whole tensors at offset 0 —
/// the graceful default for stores written outside the reshard path.
pub fn parse_shard_blob_name(blob: &str) -> (&str, u64) {
    if let Some((tensor, off)) = blob.rsplit_once('@') {
        if let Ok(off) = off.parse::<u64>() {
            return (tensor, off);
        }
    }
    (blob, 0)
}

/// One physical extent holding a slice of a logical tensor.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardExtent {
    /// File path relative to the checkpoint root.
    pub path: String,
    /// Byte offset within the file.
    pub file_off: u64,
    /// Byte offset within the logical tensor.
    pub logical_off: u64,
    pub len: u64,
}

impl ShardExtent {
    pub fn logical_end(&self) -> u64 {
        self.logical_off + self.len
    }
}

/// A logical tensor and the source extents tiling it.
#[derive(Debug, Clone)]
pub struct LogicalTensor {
    pub name: String,
    /// Total logical bytes.
    pub len: u64,
    pub mode: DpMode,
    /// Sorted by `logical_off`; tiles `[0, len)` exactly.
    pub extents: Vec<ShardExtent>,
    /// Alternate serving copies: extents holding the same
    /// `(logical_off, len)` slice as some primary extent but stored by
    /// another rank (tp-replicated tensors, explicit dp-replica shard
    /// blobs). The planner may serve a fragment from any copy — see
    /// [`crate::reshard::ReadPlanner`]'s `balance_replicas`.
    pub alts: Vec<ShardExtent>,
}

impl LogicalTensor {
    /// Every serving copy of the primary extent `e`: `e` itself plus
    /// the alternates duplicating its exact `(logical_off, len)` range.
    pub fn copies_of<'a>(&'a self, e: &'a ShardExtent) -> Vec<&'a ShardExtent> {
        let mut out = vec![e];
        out.extend(
            self.alts
                .iter()
                .filter(|a| a.logical_off == e.logical_off && a.len == e.len),
        );
        out
    }
}

/// The global shard index of one checkpoint (see the module docs).
#[derive(Debug, Clone)]
pub struct ShardIndex {
    /// Keyed (and therefore iterated) by logical tensor name — the
    /// canonical inventory order every topology's slicing agrees on.
    pub tensors: BTreeMap<String, LogicalTensor>,
    /// World size of the topology the checkpoint was saved under.
    pub source_world: usize,
}

impl ShardIndex {
    /// Total logical payload bytes.
    pub fn payload_bytes(&self) -> u64 {
        self.tensors.values().map(|t| t.len).sum()
    }

    /// The `(name, len, mode)` inventory in canonical (name) order —
    /// what the target-slicing math consumes.
    pub fn inventory(&self) -> Vec<(String, u64, DpMode)> {
        self.tensors
            .values()
            .map(|t| (t.name.clone(), t.len, t.mode))
            .collect()
    }

    /// Build the index from a real store's `ckpt.manifest.json`.
    pub fn from_store(root: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(root.join("ckpt.manifest.json"))
            .map_err(|e| Error::Format(format!("shard index: missing store manifest: {e}")))?;
        let side = Json::parse(&text).map_err(Error::Format)?;
        let ranks = side
            .get("ranks")
            .and_then(Json::as_u64)
            .ok_or_else(|| Error::format("shard index: manifest ranks"))? as usize;
        let items = side
            .get("items")
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::format("shard index: manifest items"))?;
        let mut tagged: BTreeMap<String, Vec<(ShardExtent, bool)>> = BTreeMap::new();
        for it in items {
            let kind = it.get("kind").and_then(Json::as_str).unwrap_or("");
            if kind != "tensor" {
                continue;
            }
            let get = |k: &str| -> Result<&Json> {
                it.get(k)
                    .ok_or_else(|| Error::format(format!("shard index: item missing {k}")))
            };
            let blob = get("name")?.as_str().unwrap_or("").to_string();
            let (tensor, logical_off) = parse_shard_blob_name(&blob);
            // Was the offset explicit in the blob name? Only explicit
            // shards may legitimately duplicate across ranks (dp
            // replicas); same-name whole blobs from several ranks are
            // distinct tensors that happen to collide — refusing beats
            // silently serving one rank's shard as the whole tensor.
            let explicit = blob
                .rsplit_once('@')
                .is_some_and(|(_, off)| off.parse::<u64>().is_ok());
            tagged.entry(tensor.to_string()).or_default().push((
                ShardExtent {
                    path: get("path")?.as_str().unwrap_or("").to_string(),
                    file_off: get("offset")?.as_u64().unwrap_or(0),
                    logical_off,
                    len: get("len")?.as_u64().unwrap_or(0),
                },
                explicit,
            ));
        }
        let mut raw: BTreeMap<String, Vec<ShardExtent>> = BTreeMap::new();
        for (name, mut exts) in tagged {
            exts.sort_by_key(|(e, _)| (e.logical_off, e.len));
            for w in exts.windows(2) {
                let ((a, ea), (b, eb)) = (&w[0], &w[1]);
                if a.logical_off == b.logical_off && a.len == b.len && !(*ea && *eb) {
                    return Err(Error::Integrity(format!(
                        "shard index: {name}: same-name blobs from multiple ranks without \
                         @offset shard names — not a resharded store"
                    )));
                }
            }
            raw.insert(name, exts.into_iter().map(|(e, _)| e).collect());
        }
        Self::finish(raw, ranks)
    }

    /// Build the index analytically from a model spec, the source
    /// parallelism, and the aggregation strategy the checkpoint was
    /// written under — extents come from the same offset planner the
    /// engines compile plans from, so the index matches what an engine
    /// actually put on disk (or what the simulator models), byte for
    /// byte. The logical tensor is defined as the concatenation of its
    /// source shards in canonical `(pp, tp, dp)` order; tensors the
    /// layout replicates across tp (layer norms) index tp rank 0's copy.
    pub fn from_layout(spec: &ModelSpec, par: Parallelism, agg: Aggregation) -> Result<Self> {
        let layout = CheckpointLayout::derive(spec, par);
        // Which model tensors tp actually shards (the layout flattens
        // that flag away).
        let mut shardable: BTreeMap<String, bool> = BTreeMap::new();
        for layer in 0..spec.n_layers {
            for t in spec.layer_tensors(layer) {
                shardable.insert(t.name.clone(), t.tp_shardable);
            }
        }
        for t in spec.edge_tensors() {
            shardable.insert(t.name.clone(), t.tp_shardable);
        }

        struct Piece {
            key: (usize, usize, usize),
            ext: ShardExtent,
        }
        let bases = shared_file_bases(&layout.shards, DIRECT_IO_ALIGN);
        let mut pieces: BTreeMap<String, Vec<Piece>> = BTreeMap::new();
        let mut alts: BTreeMap<String, Vec<ShardExtent>> = BTreeMap::new();
        for (i, shard) in layout.shards.iter().enumerate() {
            let c = par.coord(shard.rank);
            let offsets = plan_offsets(agg, shard, bases[i], DIRECT_IO_ALIGN);
            for item in &offsets.items {
                if !matches!(item.kind, ItemKind::Tensor { .. }) {
                    continue;
                }
                // tp-replicated tensors: tp rank 0's copy is the
                // primary tiling; the other tp ranks' identical copies
                // index as alternate serving extents (whole-tensor
                // copies at logical offset 0) so a restore storm can
                // load-balance across them instead of hammering rank 0.
                if shardable.get(&item.name) == Some(&false) && c.tp != 0 {
                    alts.entry(item.name.clone()).or_default().push(ShardExtent {
                        path: offsets.files[item.file].path.clone(),
                        file_off: item.offset,
                        logical_off: 0,
                        len: item.len,
                    });
                    continue;
                }
                // Under ZeRO stage 0 the layout replicates optimizer
                // shards across dp — index dp rank 0's copy only, or
                // the prefix sum would inflate the logical tensor by
                // the duplicated bytes.
                if par.zero_stage == 0 && DpMode::of_name(&item.name) == DpMode::Partitioned && c.dp != 0
                {
                    continue;
                }
                pieces.entry(item.name.clone()).or_default().push(Piece {
                    key: (c.pp, c.tp, c.dp),
                    ext: ShardExtent {
                        path: offsets.files[item.file].path.clone(),
                        file_off: item.offset,
                        logical_off: 0, // assigned below by prefix sum
                        len: item.len,
                    },
                });
            }
        }
        let mut raw: BTreeMap<String, Vec<ShardExtent>> = BTreeMap::new();
        for (name, mut ps) in pieces {
            ps.sort_by_key(|p| p.key);
            let mut cursor = 0u64;
            let exts = ps
                .into_iter()
                .map(|p| {
                    let mut e = p.ext;
                    e.logical_off = cursor;
                    cursor += e.len;
                    e
                })
                .collect();
            raw.insert(name, exts);
        }
        Self::finish_with_alts(raw, alts, par.world())
    }

    /// [`Self::finish_with_alts`] with no out-of-band alternates.
    fn finish(raw: BTreeMap<String, Vec<ShardExtent>>, source_world: usize) -> Result<Self> {
        Self::finish_with_alts(raw, BTreeMap::new(), source_world)
    }

    /// Sort, move duplicate serving copies (dp replicas) into the
    /// alternate list, and check the tiling invariant. `extra_alts`
    /// carries alternates discovered before tiling (tp-replicated
    /// copies under [`Self::from_layout`]); every alternate must
    /// duplicate a primary extent's exact `(logical_off, len)` range.
    fn finish_with_alts(
        raw: BTreeMap<String, Vec<ShardExtent>>,
        mut extra_alts: BTreeMap<String, Vec<ShardExtent>>,
        source_world: usize,
    ) -> Result<Self> {
        let mut tensors = BTreeMap::new();
        for (name, mut exts) in raw {
            exts.sort_by_key(|e| (e.logical_off, e.path.clone(), e.file_off));
            // dp replicas store the same (logical_off, len) slice from
            // different ranks: the first copy serves as the primary
            // tiling, the rest become alternate serving copies.
            let mut alts = extra_alts.remove(&name).unwrap_or_default();
            let mut primary: Vec<ShardExtent> = Vec::with_capacity(exts.len());
            for e in exts {
                match primary.last() {
                    Some(p) if p.logical_off == e.logical_off && p.len == e.len => alts.push(e),
                    _ => primary.push(e),
                }
            }
            let mut cursor = 0u64;
            for e in &primary {
                if e.logical_off != cursor {
                    return Err(Error::Integrity(format!(
                        "shard index: {name}: extent at logical {} but cursor {cursor} \
                         (gap or overlap)",
                        e.logical_off
                    )));
                }
                cursor += e.len;
            }
            for a in &alts {
                let dup = primary
                    .iter()
                    .any(|p| p.logical_off == a.logical_off && p.len == a.len);
                if !dup {
                    return Err(Error::Integrity(format!(
                        "shard index: {name}: alternate copy at logical {} len {} \
                         matches no primary extent",
                        a.logical_off, a.len
                    )));
                }
            }
            alts.sort_by_key(|e| (e.logical_off, e.path.clone(), e.file_off));
            let mode = DpMode::of_name(&name);
            tensors.insert(
                name.clone(),
                LogicalTensor {
                    name,
                    len: cursor,
                    mode,
                    extents: primary,
                    alts,
                },
            );
        }
        if tensors.is_empty() {
            return Err(Error::format("shard index: no tensor extents"));
        }
        Ok(Self {
            tensors,
            source_world,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blob_name_roundtrip() {
        let n = shard_blob_name("layers.3.attn.qkv.weight", 4096);
        assert_eq!(parse_shard_blob_name(&n), ("layers.3.attn.qkv.weight", 4096));
        // Whole-blob names (no suffix / unparsable suffix) map to offset 0.
        assert_eq!(parse_shard_blob_name("t0"), ("t0", 0));
        assert_eq!(parse_shard_blob_name("a@b"), ("a@b", 0));
    }

    #[test]
    fn dp_mode_convention() {
        assert_eq!(DpMode::of_name("optim.exp_avg"), DpMode::Partitioned);
        assert_eq!(DpMode::of_name("layers.0.mlp.up.weight"), DpMode::Replicated);
    }

    #[test]
    fn from_layout_tiles_every_tensor() {
        let spec = ModelSpec::tiny_100m();
        let par = Parallelism::new(2, 2, 2);
        let idx = ShardIndex::from_layout(&spec, par, Aggregation::FilePerProcess).unwrap();
        assert_eq!(idx.source_world, 8);
        assert!(idx.payload_bytes() > 0);
        for t in idx.tensors.values() {
            let mut cursor = 0;
            for e in &t.extents {
                assert_eq!(e.logical_off, cursor, "{}", t.name);
                cursor += e.len;
            }
            assert_eq!(cursor, t.len, "{}", t.name);
        }
        // Optimizer state is partitioned and spans the whole grid; a
        // sharded layer matrix has one extent per tp rank.
        let optim = &idx.tensors["optim.fp32_master"];
        assert_eq!(optim.mode, DpMode::Partitioned);
        assert_eq!(optim.extents.len(), par.world());
        let qkv = &idx.tensors["layers.0.attn.qkv.weight"];
        assert_eq!(qkv.mode, DpMode::Replicated);
        assert_eq!(qkv.extents.len(), par.tp);
        // tp-replicated layer norms index a single primary copy, with
        // the other tp ranks' identical copies as alternates.
        let ln = &idx.tensors["layers.0.ln_attn.weight"];
        assert_eq!(ln.extents.len(), 1);
        assert_eq!(ln.alts.len(), par.tp - 1);
        for a in &ln.alts {
            assert_eq!((a.logical_off, a.len), (0, ln.len));
            assert_ne!(a.path, ln.extents[0].path);
        }
        assert_eq!(ln.copies_of(&ln.extents[0]).len(), par.tp);
        // Sharded tensors have no whole-copy alternates.
        assert!(qkv.alts.is_empty());
    }

    #[test]
    fn from_store_rejects_ambiguous_whole_blob_duplicates() {
        let dir = std::env::temp_dir().join(format!("ckptio-shardidx-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        // Two ranks storing the same suffix-less blob name: distinct
        // shards colliding, not dp replicas — must refuse.
        let manifest = r#"{"ranks":2,"items":[
          {"name":"w","rank":0,"path":"rank000.bin","offset":4096,"len":100,"kind":"tensor"},
          {"name":"w","rank":1,"path":"rank001.bin","offset":4096,"len":100,"kind":"tensor"}
        ]}"#;
        std::fs::write(dir.join("ckpt.manifest.json"), manifest).unwrap();
        let err = ShardIndex::from_store(&dir).unwrap_err();
        assert!(err.to_string().contains("not a resharded store"), "{err}");
        // Explicit @offset duplicates (dp replicas) deduplicate fine.
        let manifest = r#"{"ranks":2,"items":[
          {"name":"w@0","rank":0,"path":"rank000.bin","offset":4096,"len":100,"kind":"tensor"},
          {"name":"w@0","rank":1,"path":"rank001.bin","offset":4096,"len":100,"kind":"tensor"}
        ]}"#;
        std::fs::write(dir.join("ckpt.manifest.json"), manifest).unwrap();
        let idx = ShardIndex::from_store(&dir).unwrap();
        assert_eq!(idx.tensors["w"].len, 100);
        assert_eq!(idx.tensors["w"].extents.len(), 1);
        // The second rank's identical shard survives as an alternate
        // serving copy instead of being dropped.
        assert_eq!(idx.tensors["w"].alts.len(), 1);
        assert_eq!(idx.tensors["w"].alts[0].path, "rank001.bin");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn zero_stage_0_optimizer_not_inflated() {
        // ZeRO stage 0 replicates optimizer shards across dp; the
        // index must carry one copy, not dp concatenated duplicates.
        let spec = ModelSpec::tiny_100m();
        let mut par = Parallelism::new(2, 1, 2);
        par.zero_stage = 0;
        let idx = ShardIndex::from_layout(&spec, par, Aggregation::FilePerProcess).unwrap();
        let no_dp = Parallelism::new(2, 1, 1);
        let idx1 = ShardIndex::from_layout(&spec, no_dp, Aggregation::FilePerProcess).unwrap();
        for t in ["optim.fp32_master", "optim.exp_avg", "optim.exp_avg_sq"] {
            assert_eq!(idx.tensors[t].len, idx1.tensors[t].len, "{t}");
            assert_eq!(idx.tensors[t].extents.len(), par.tp, "{t}");
        }
    }

    #[test]
    fn finish_rejects_gaps_and_overlaps() {
        let ext = |lo: u64, len: u64| ShardExtent {
            path: "f".into(),
            file_off: lo,
            logical_off: lo,
            len,
        };
        let mut raw = BTreeMap::new();
        raw.insert("t".to_string(), vec![ext(0, 10), ext(12, 4)]);
        assert!(ShardIndex::finish(raw, 1).is_err());
        let mut raw = BTreeMap::new();
        raw.insert("t".to_string(), vec![ext(0, 10), ext(8, 4)]);
        assert!(ShardIndex::finish(raw, 1).is_err());
        let mut raw = BTreeMap::new();
        raw.insert("t".to_string(), vec![ext(0, 10), ext(10, 4)]);
        let idx = ShardIndex::finish(raw, 1).unwrap();
        assert_eq!(idx.tensors["t"].len, 14);
    }
}
