//! The asynchronous drain path: copy committed checkpoints between
//! tiers through the same per-tier I/O backends plans execute on.
//!
//! A drain batch is two [`crate::exec::real::RealExecutor`] runs
//! sharing one staging buffer: a read plan rooted at the source tier
//! (its backend) pulls data blocks into staging, then a write plan
//! rooted at the destination tier (its backend) pushes them out and
//! fsyncs. Staging memory is bounded: files are windowed and copied in
//! batches of at most [`BATCH_BYTES`], so draining a checkpoint larger
//! than host memory never materializes it whole. The destination
//! manifest is committed by the caller strictly *after* every batch
//! lands (see [`super::cascade`]), so a crash mid-drain leaves the
//! destination uncommitted and the source intact.

use std::collections::BTreeMap;
use std::path::Path;

use crate::error::Result;
use crate::exec::real::{BackendKind, RealExecutor};
use crate::plan::{FileSpec, PlanOp, RankPlan};
use crate::uring::AlignedBuf;
use crate::util::bytes::MIB;

/// Transfer chunk size for tier-to-tier copies.
const DRAIN_CHUNK: u64 = 8 * MIB;

/// Upper bound on staging memory per drain batch.
pub const BATCH_BYTES: u64 = 256 * MIB;

/// One contiguous byte range of one file.
struct Window<'a> {
    path: &'a str,
    /// Full length of the file (for preallocation on the write side).
    file_len: u64,
    offset: u64,
    len: u64,
}

/// Copy the named files (`(relative path, length)`) from `src_root` to
/// `dst_root`, reading through `src_backend` and writing (+fsync)
/// through `dst_backend`. Returns the bytes moved.
pub fn copy_files(
    files: &[(String, u64)],
    src_root: &Path,
    dst_root: &Path,
    src_backend: BackendKind,
    dst_backend: BackendKind,
    queue_depth: u32,
) -> Result<u64> {
    // Expand files into windows no larger than a batch.
    let mut windows: Vec<Window> = Vec::new();
    for (path, len) in files {
        if *len == 0 {
            // Nothing to transfer; just materialize the empty file.
            let p = dst_root.join(path);
            if let Some(parent) = p.parent() {
                std::fs::create_dir_all(parent)?;
            }
            std::fs::File::create(p)?;
            continue;
        }
        let mut off = 0;
        while off < *len {
            let n = (*len - off).min(BATCH_BYTES);
            windows.push(Window {
                path: path.as_str(),
                file_len: *len,
                offset: off,
                len: n,
            });
            off += n;
        }
    }

    let mut total = 0u64;
    let mut i = 0;
    while i < windows.len() {
        // Greedily take windows up to the batch budget (always >= 1).
        let mut batch_bytes = 0u64;
        let mut j = i;
        while j < windows.len() && (j == i || batch_bytes + windows[j].len <= BATCH_BYTES) {
            batch_bytes += windows[j].len;
            j += 1;
        }
        copy_batch(
            &windows[i..j],
            batch_bytes,
            src_root,
            dst_root,
            src_backend,
            dst_backend,
            queue_depth,
        )?;
        total += batch_bytes;
        i = j;
    }
    Ok(total)
}

#[allow(clippy::too_many_arguments)]
fn copy_batch(
    windows: &[Window],
    batch_bytes: u64,
    src_root: &Path,
    dst_root: &Path,
    src_backend: BackendKind,
    dst_backend: BackendKind,
    queue_depth: u32,
) -> Result<()> {
    let mut read_plan = RankPlan::new(0, 0);
    let mut write_plan = RankPlan::new(0, 0);
    // path → (read file id, write file id) within this batch.
    let mut ids: BTreeMap<&str, (usize, usize)> = BTreeMap::new();
    let mut cursor = 0u64;
    for w in windows {
        let (rf, wf) = match ids.get(w.path) {
            Some(&pair) => pair,
            None => {
                let rf = read_plan.add_file(FileSpec {
                    path: w.path.to_string(),
                    direct: false,
                    size_hint: 0,
                    creates: false,
                });
                read_plan.push(PlanOp::Open { file: rf });
                // `creates` + full-length size hint is idempotent across
                // batches: the file is preallocated once and re-opened.
                let wf = write_plan.add_file(FileSpec {
                    path: w.path.to_string(),
                    direct: false,
                    size_hint: w.file_len,
                    creates: true,
                });
                write_plan.push(PlanOp::Create { file: wf });
                ids.insert(w.path, (rf, wf));
                (rf, wf)
            }
        };
        crate::engines::push_chunked(&mut read_plan, false, rf, w.offset, cursor, w.len, DRAIN_CHUNK);
        crate::engines::push_chunked(&mut write_plan, true, wf, w.offset, cursor, w.len, DRAIN_CHUNK);
        cursor += w.len;
    }
    read_plan.push(PlanOp::Drain);
    write_plan.push(PlanOp::Drain);
    for f in 0..write_plan.files.len() {
        write_plan.push(PlanOp::Fsync { file: f });
    }

    let mut staging = vec![AlignedBuf::zeroed(batch_bytes.max(4096) as usize)];
    RealExecutor::new(src_root, src_backend)
        .with_queue_depth(queue_depth)
        .run(&[read_plan], &mut staging)?;
    RealExecutor::new(dst_root, dst_backend)
        .with_queue_depth(queue_depth)
        .run(&[write_plan], &mut staging)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Xoshiro256;
    use std::path::PathBuf;

    fn tmp(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("ckptio-wb-{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn copy_files_bitexact() {
        let src = tmp("src");
        let dst = tmp("dst");
        let mut rng = Xoshiro256::seeded(9);
        let mut a = vec![0u8; 100_000];
        rng.fill_bytes(&mut a);
        std::fs::write(src.join("a.bin"), &a).unwrap();
        std::fs::create_dir_all(src.join("sub")).unwrap();
        std::fs::write(src.join("sub/b.bin"), b"tiny").unwrap();

        let files = vec![
            ("a.bin".to_string(), 100_000u64),
            ("sub/b.bin".to_string(), 4u64),
        ];
        let moved = copy_files(
            &files,
            &src,
            &dst,
            BackendKind::Posix,
            BackendKind::Posix,
            8,
        )
        .unwrap();
        assert_eq!(moved, 100_004);
        assert_eq!(std::fs::read(dst.join("a.bin")).unwrap(), a);
        assert_eq!(std::fs::read(dst.join("sub/b.bin")).unwrap(), b"tiny");
        std::fs::remove_dir_all(&src).unwrap();
        std::fs::remove_dir_all(&dst).unwrap();
    }

    #[test]
    fn batching_still_bitexact_with_tiny_windows() {
        // Force many windows/batches by copying files that together
        // exceed several DRAIN_CHUNKs, via the public API (BATCH_BYTES
        // itself is too large to exercise cheaply, so rely on multiple
        // files + sub-chunk tails instead).
        let src = tmp("batch-src");
        let dst = tmp("batch-dst");
        let mut rng = Xoshiro256::seeded(42);
        let mut files = Vec::new();
        for i in 0..5 {
            let n = 3 * MIB as usize + i * 12_345;
            let mut b = vec![0u8; n];
            rng.fill_bytes(&mut b);
            std::fs::write(src.join(format!("f{i}.bin")), &b).unwrap();
            files.push((format!("f{i}.bin"), n as u64));
        }
        let expect: u64 = files.iter().map(|(_, n)| n).sum();
        let moved = copy_files(
            &files,
            &src,
            &dst,
            BackendKind::Posix,
            BackendKind::Posix,
            8,
        )
        .unwrap();
        assert_eq!(moved, expect);
        for (name, _) in &files {
            assert_eq!(
                std::fs::read(src.join(name)).unwrap(),
                std::fs::read(dst.join(name)).unwrap(),
                "{name}"
            );
        }
        std::fs::remove_dir_all(&src).unwrap();
        std::fs::remove_dir_all(&dst).unwrap();
    }

    #[test]
    fn empty_file_list_is_noop() {
        let src = tmp("e-src");
        let dst = tmp("e-dst");
        assert_eq!(
            copy_files(&[], &src, &dst, BackendKind::Posix, BackendKind::Posix, 8).unwrap(),
            0
        );
        std::fs::remove_dir_all(&src).unwrap();
        std::fs::remove_dir_all(&dst).unwrap();
    }
}
