//! The copies registry: one lock spanning cascade and replica eviction
//! decisions.
//!
//! Before this existed, [`super::TierCascade`] and
//! [`super::ReplicaTier`] each guarded their own copy accounting, and a
//! replica eviction's "is this step durable on the PFS?" check could
//! interleave with a concurrent PFS eviction — a sub-microsecond window
//! in which both sides could drop what each believed was a redundant
//! copy. The registry closes it: both structures record their committed
//! copies here, and **every eviction decision (the durable-elsewhere
//! check plus the removal it justifies) runs while holding the registry
//! lock**, so the two sides serialize.
//!
//! Lock ordering discipline (deadlock freedom): the registry lock is
//! always acquired *before* any component lock (`TierCascade`'s state
//! mutex, `ReplicaTier`'s state mutex); recording updates that do not
//! gate an eviction take the registry lock alone, after releasing the
//! component lock. No code path acquires the registry while holding a
//! component lock.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::{Mutex, MutexGuard};

/// The shared copy accounting (held behind [`CopiesRegistry::lock`]).
#[derive(Debug, Default)]
pub struct Copies {
    /// step → storage tiers holding a committed copy.
    storage: BTreeMap<u64, BTreeSet<usize>>,
    /// step → buddy nodes holding an acked replica.
    replicas: BTreeMap<u64, BTreeSet<usize>>,
    /// step → holder nodes with a committed erasure **strip**. A strip
    /// is a *fraction* of a copy: it never enters [`Self::durable_at`]
    /// or the replica accounting, and only
    /// [`Self::erasure_recoverable`] (≥ k strips reachable) may count
    /// the stripe as a surviving copy.
    strips: BTreeMap<u64, BTreeSet<usize>>,
    /// step → the stripe's data-strip count k (how many strips must
    /// survive for the step to reconstruct).
    strip_k: BTreeMap<u64, usize>,
    /// Lifetime count of storage-copy records actually dropped.
    storage_drops: u64,
    /// Lifetime count of replica records actually dropped.
    replica_drops: u64,
    /// Lifetime count of strip records actually dropped.
    strip_drops: u64,
}

impl Copies {
    pub fn record_storage(&mut self, tier: usize, step: u64) {
        self.storage.entry(step).or_default().insert(tier);
    }

    /// Returns whether a copy was actually dropped (the caller's
    /// registry tallies real drops, not no-op repeats).
    pub fn drop_storage(&mut self, tier: usize, step: u64) -> bool {
        if let Some(s) = self.storage.get_mut(&step) {
            let removed = s.remove(&tier);
            if s.is_empty() {
                self.storage.remove(&step);
            }
            self.storage_drops += u64::from(removed);
            removed
        } else {
            false
        }
    }

    pub fn record_replica(&mut self, buddy: usize, step: u64) {
        self.replicas.entry(step).or_default().insert(buddy);
    }

    /// Returns whether a replica record was actually dropped.
    pub fn drop_replica(&mut self, buddy: usize, step: u64) -> bool {
        if let Some(s) = self.replicas.get_mut(&step) {
            let removed = s.remove(&buddy);
            if s.is_empty() {
                self.replicas.remove(&step);
            }
            self.replica_drops += u64::from(removed);
            removed
        } else {
            false
        }
    }

    /// Record a committed erasure strip of `step` at `holder`; `k` is
    /// the stripe's data-strip count (constant per step — the last
    /// recorded value wins across a re-encode with new geometry).
    pub fn record_strip(&mut self, holder: usize, step: u64, k: usize) {
        self.strips.entry(step).or_default().insert(holder);
        self.strip_k.insert(step, k.max(1));
    }

    /// Returns whether a strip record was actually dropped.
    pub fn drop_strip(&mut self, holder: usize, step: u64) -> bool {
        if let Some(s) = self.strips.get_mut(&step) {
            let removed = s.remove(&holder);
            if s.is_empty() {
                self.strips.remove(&step);
                self.strip_k.remove(&step);
            }
            self.strip_drops += u64::from(removed);
            removed
        } else {
            false
        }
    }

    /// Holders with a committed strip of `step`.
    pub fn strip_count(&self, step: u64) -> usize {
        self.strips.get(&step).map(|s| s.len()).unwrap_or(0)
    }

    /// True when ≥ k strips of `step` survive — the stripe counts as
    /// one surviving (reconstructible) copy. This, **never** a raw
    /// strip count, is what eviction and durability logic may treat as
    /// a copy: a node holding one strip holds nothing restorable.
    pub fn erasure_recoverable(&self, step: u64) -> bool {
        match self.strip_k.get(&step) {
            Some(&k) => self.strip_count(step) >= k,
            None => false,
        }
    }

    /// Is `step` committed at storage tier `tier`? Strips are
    /// deliberately invisible here — partial copies never satisfy a
    /// whole-copy durability check.
    pub fn durable_at(&self, tier: usize, step: u64) -> bool {
        self.storage.get(&step).is_some_and(|s| s.contains(&tier))
    }

    /// Steps committed at storage tier `tier`, ascending.
    pub fn storage_steps(&self, tier: usize) -> Vec<u64> {
        self.storage
            .iter()
            .filter(|(_, tiers)| tiers.contains(&tier))
            .map(|(&s, _)| s)
            .collect()
    }

    /// Steps with at least one acked replica, ascending.
    pub fn replica_steps(&self) -> Vec<u64> {
        self.replicas.keys().copied().collect()
    }
}

impl CopiesRegistry {
    /// Lifetime `(storage, replica)` drop tallies — how many committed
    /// copies each eviction side actually removed from the accounting.
    pub fn drop_counts(&self) -> (u64, u64) {
        let c = self.lock();
        (c.storage_drops, c.replica_drops)
    }

    /// Lifetime strip-record drop tally (the erasure eviction side).
    pub fn strip_drop_count(&self) -> u64 {
        self.lock().strip_drops
    }
}

/// The single lock + accounting both eviction sides consult (see the
/// module docs).
#[derive(Debug)]
pub struct CopiesRegistry {
    /// Index of the cascade's slowest (most durable) storage tier.
    /// When this is 0 the cascade is single-tier — the "slowest tier"
    /// is the node's own burst buffer, which dies with the node, so
    /// nothing counts as durable-elsewhere through it.
    slowest_tier: usize,
    state: Mutex<Copies>,
}

impl CopiesRegistry {
    pub fn new(slowest_tier: usize) -> Self {
        Self {
            slowest_tier,
            state: Mutex::new(Copies::default()),
        }
    }

    pub fn slowest_tier(&self) -> usize {
        self.slowest_tier
    }

    /// Acquire the registry. Hold the guard across an entire eviction
    /// decision (check + removal); never acquire while holding a
    /// component lock.
    pub fn lock(&self) -> MutexGuard<'_, Copies> {
        self.state.lock().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_drop_roundtrip() {
        let reg = CopiesRegistry::new(1);
        let mut c = reg.lock();
        c.record_storage(0, 5);
        c.record_storage(1, 5);
        c.record_replica(2, 5);
        assert!(c.durable_at(1, 5));
        assert_eq!(c.storage_steps(1), vec![5]);
        assert_eq!(c.replica_steps(), vec![5]);
        c.drop_storage(1, 5);
        assert!(!c.durable_at(1, 5));
        assert!(c.durable_at(0, 5));
        assert!(c.drop_replica(2, 5));
        assert!(c.replica_steps().is_empty());
        // Dropping what is not there is a no-op (and not counted).
        assert!(!c.drop_storage(3, 99));
        assert!(!c.drop_replica(3, 99));
        drop(c);
        assert_eq!(reg.drop_counts(), (1, 1));
    }

    #[test]
    fn strips_never_count_as_whole_copies() {
        let reg = CopiesRegistry::new(1);
        let mut c = reg.lock();
        // RS(k=2, m=1): three strips of step 7 across three holders.
        for h in [1, 2, 3] {
            c.record_strip(h, 7, 2);
        }
        // Strips are invisible to whole-copy durability and replica
        // accounting — a strip holder holds nothing restorable alone.
        assert!(!c.durable_at(0, 7));
        assert!(!c.durable_at(1, 7));
        assert!(c.replica_steps().is_empty());
        assert_eq!(c.strip_count(7), 3);
        assert!(c.erasure_recoverable(7));
        // Lose one holder: still ≥ k.
        assert!(c.drop_strip(3, 7));
        assert!(c.erasure_recoverable(7));
        // Lose another: below k — no longer a surviving copy.
        assert!(c.drop_strip(2, 7));
        assert!(!c.erasure_recoverable(7));
        assert_eq!(c.strip_count(7), 1);
        // Dropping what is not there is a no-op (and not counted).
        assert!(!c.drop_strip(9, 7));
        assert!(!c.erasure_recoverable(99));
        drop(c);
        assert_eq!(reg.strip_drop_count(), 2);
        assert_eq!(reg.drop_counts(), (0, 0));
    }

    #[test]
    fn slowest_tier_recorded() {
        assert_eq!(CopiesRegistry::new(0).slowest_tier(), 0);
        assert_eq!(CopiesRegistry::new(2).slowest_tier(), 2);
    }
}
