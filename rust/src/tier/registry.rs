//! The copies registry: one lock spanning cascade and replica eviction
//! decisions.
//!
//! Before this existed, [`super::TierCascade`] and
//! [`super::ReplicaTier`] each guarded their own copy accounting, and a
//! replica eviction's "is this step durable on the PFS?" check could
//! interleave with a concurrent PFS eviction — a sub-microsecond window
//! in which both sides could drop what each believed was a redundant
//! copy. The registry closes it: both structures record their committed
//! copies here, and **every eviction decision (the durable-elsewhere
//! check plus the removal it justifies) runs while holding the registry
//! lock**, so the two sides serialize.
//!
//! Lock ordering discipline (deadlock freedom): the registry lock is
//! always acquired *before* any component lock (`TierCascade`'s state
//! mutex, `ReplicaTier`'s state mutex); recording updates that do not
//! gate an eviction take the registry lock alone, after releasing the
//! component lock. No code path acquires the registry while holding a
//! component lock.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::{Mutex, MutexGuard};

/// The shared copy accounting (held behind [`CopiesRegistry::lock`]).
#[derive(Debug, Default)]
pub struct Copies {
    /// step → storage tiers holding a committed copy.
    storage: BTreeMap<u64, BTreeSet<usize>>,
    /// step → buddy nodes holding an acked replica.
    replicas: BTreeMap<u64, BTreeSet<usize>>,
    /// Lifetime count of storage-copy records actually dropped.
    storage_drops: u64,
    /// Lifetime count of replica records actually dropped.
    replica_drops: u64,
}

impl Copies {
    pub fn record_storage(&mut self, tier: usize, step: u64) {
        self.storage.entry(step).or_default().insert(tier);
    }

    /// Returns whether a copy was actually dropped (the caller's
    /// registry tallies real drops, not no-op repeats).
    pub fn drop_storage(&mut self, tier: usize, step: u64) -> bool {
        if let Some(s) = self.storage.get_mut(&step) {
            let removed = s.remove(&tier);
            if s.is_empty() {
                self.storage.remove(&step);
            }
            self.storage_drops += u64::from(removed);
            removed
        } else {
            false
        }
    }

    pub fn record_replica(&mut self, buddy: usize, step: u64) {
        self.replicas.entry(step).or_default().insert(buddy);
    }

    /// Returns whether a replica record was actually dropped.
    pub fn drop_replica(&mut self, buddy: usize, step: u64) -> bool {
        if let Some(s) = self.replicas.get_mut(&step) {
            let removed = s.remove(&buddy);
            if s.is_empty() {
                self.replicas.remove(&step);
            }
            self.replica_drops += u64::from(removed);
            removed
        } else {
            false
        }
    }

    /// Is `step` committed at storage tier `tier`?
    pub fn durable_at(&self, tier: usize, step: u64) -> bool {
        self.storage.get(&step).is_some_and(|s| s.contains(&tier))
    }

    /// Steps committed at storage tier `tier`, ascending.
    pub fn storage_steps(&self, tier: usize) -> Vec<u64> {
        self.storage
            .iter()
            .filter(|(_, tiers)| tiers.contains(&tier))
            .map(|(&s, _)| s)
            .collect()
    }

    /// Steps with at least one acked replica, ascending.
    pub fn replica_steps(&self) -> Vec<u64> {
        self.replicas.keys().copied().collect()
    }
}

impl CopiesRegistry {
    /// Lifetime `(storage, replica)` drop tallies — how many committed
    /// copies each eviction side actually removed from the accounting.
    pub fn drop_counts(&self) -> (u64, u64) {
        let c = self.lock();
        (c.storage_drops, c.replica_drops)
    }
}

/// The single lock + accounting both eviction sides consult (see the
/// module docs).
#[derive(Debug)]
pub struct CopiesRegistry {
    /// Index of the cascade's slowest (most durable) storage tier.
    /// When this is 0 the cascade is single-tier — the "slowest tier"
    /// is the node's own burst buffer, which dies with the node, so
    /// nothing counts as durable-elsewhere through it.
    slowest_tier: usize,
    state: Mutex<Copies>,
}

impl CopiesRegistry {
    pub fn new(slowest_tier: usize) -> Self {
        Self {
            slowest_tier,
            state: Mutex::new(Copies::default()),
        }
    }

    pub fn slowest_tier(&self) -> usize {
        self.slowest_tier
    }

    /// Acquire the registry. Hold the guard across an entire eviction
    /// decision (check + removal); never acquire while holding a
    /// component lock.
    pub fn lock(&self) -> MutexGuard<'_, Copies> {
        self.state.lock().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_drop_roundtrip() {
        let reg = CopiesRegistry::new(1);
        let mut c = reg.lock();
        c.record_storage(0, 5);
        c.record_storage(1, 5);
        c.record_replica(2, 5);
        assert!(c.durable_at(1, 5));
        assert_eq!(c.storage_steps(1), vec![5]);
        assert_eq!(c.replica_steps(), vec![5]);
        c.drop_storage(1, 5);
        assert!(!c.durable_at(1, 5));
        assert!(c.durable_at(0, 5));
        assert!(c.drop_replica(2, 5));
        assert!(c.replica_steps().is_empty());
        // Dropping what is not there is a no-op (and not counted).
        assert!(!c.drop_storage(3, 99));
        assert!(!c.drop_replica(3, 99));
        drop(c);
        assert_eq!(reg.drop_counts(), (1, 1));
    }

    #[test]
    fn slowest_tier_recorded() {
        assert_eq!(CopiesRegistry::new(0).slowest_tier(), 0);
        assert_eq!(CopiesRegistry::new(2).slowest_tier(), 2);
    }
}
