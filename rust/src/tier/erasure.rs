//! `ErasureTier` — RS(k, m) erasure-coded redundancy across failure
//! domains, the striped alternative to `ReplicaTier`'s full buddy
//! copies.
//!
//! TierCheck's cost argument: a fan-out-f buddy scheme ships f full
//! checkpoints over the peer fabric to tolerate f node losses. A
//! systematic Reed–Solomon code over GF(2^8) cuts the step into k data
//! strips plus m parity strips and tolerates **m** losses while
//! shipping only (k+m)/k of the payload — RS(4, 2) matches fan-out-2's
//! two-loss survivability at 1.5x egress instead of 2.0x (a 25% NIC
//! saving `fig27_erasure` measures under contention).
//!
//! This module provides:
//!
//! * A pure-Rust GF(2^8) codec ([`ReedSolomon`]): const-built exp/log
//!   tables over the 0x11d polynomial, a systematic generator whose
//!   parity block is a Cauchy matrix (every k×k submatrix of `[I; C]`
//!   is invertible, so **any** k surviving strips reconstruct), and a
//!   Gauss–Jordan decoder that inverts only the k×k submatrix the
//!   survivors select.
//! * [`StripePlanner`] — cuts a step's committed payload into k
//!   zero-padded strips whose width is a [`DIRECT_IO_ALIGN`] multiple,
//!   so strip files stay O_DIRECT-clean on every tier.
//! * [`ErasureTier`] — the real-storage strip store: strip i of node
//!   n's step lands at `node{holder}/from_node{n}/step_*/strip_i.bin`
//!   on k+m holders in **distinct foreign failure domains**
//!   ([`PlacementPolicy::FailureDomainAware`] refuses topologies that
//!   cannot host the spread — never silently degrade), each strip
//!   committed crash-consistently (strip bytes + [`StripeHeader`]
//!   fsynced strictly before the [`TierManifest`] temp+rename), with
//!   per-holder capacity budgets whose eviction never drops a step
//!   below k reachable strips unless the step is durable on the PFS.
//! * Degraded restore ([`ErasureTier::reconstruct_dir`]): gather any k
//!   surviving strips, decode if a data strip is missing, re-materialize
//!   the original blobs and verify them against the per-file CRCs the
//!   header recorded at encode time — bit-identity, not best-effort.
//! * [`erasure_drain_plan`] — the plan transform expressing the encode
//!   pump on the simulator: read back the step, pay the encode CPU cost
//!   ([`PlanOp::CpuWork`]), push one strip to each holder's
//!   `peer/n{h}/…` store so the (k+m)/k egress contends with PFS
//!   flushes on the node's NIC exactly like replication does.
//!
//! [`crate::tier::TierCascade::with_erasure`] attaches the tier beside
//! (or instead of) the replica tier: saves enqueue asynchronous encode
//! on the cascade pool, and the restore walk tries reconstruction at
//! replica rank — counting "≥ k strips reachable", never raw strip
//! count, as a surviving copy.

use std::collections::{BTreeMap, BTreeSet};
use std::fs;
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use crate::ckpt::store::{CheckpointStore, RankData};
use crate::coordinator::topology::Topology;
use crate::error::{Error, Result};
use crate::exec::real::BackendKind;
use crate::plan::{BufSlice, FileSpec, PlanOp, RankPlan};
use crate::util::align::{align_up, DIRECT_IO_ALIGN};
use crate::util::bytes::MIB;

use super::cascade::{parse_step_dirname, step_dirname};
use super::manifest::{ManifestFile, TierManifest};
use super::registry::{Copies, CopiesRegistry};
use super::replica::{peer_path, PlacementPolicy};

// ---------------------------------------------------------------------------
// GF(2^8) arithmetic (polynomial 0x11d), tables built at compile time.
// ---------------------------------------------------------------------------

/// Build the exp/log tables for GF(2^8) over the 0x11d polynomial. The
/// exp table is doubled (512 entries) so `exp[log a + log b]` never
/// needs a mod-255 reduction.
const fn gf_tables() -> ([u8; 512], [u8; 256]) {
    let mut exp = [0u8; 512];
    let mut log = [0u8; 256];
    let mut x: u16 = 1;
    let mut i = 0;
    while i < 255 {
        exp[i] = x as u8;
        log[x as usize] = i as u8;
        x <<= 1;
        if x & 0x100 != 0 {
            x ^= 0x11d;
        }
        i += 1;
    }
    let mut j = 255;
    while j < 510 {
        exp[j] = exp[j - 255];
        j += 1;
    }
    (exp, log)
}

const GF: ([u8; 512], [u8; 256]) = gf_tables();

#[inline]
fn gf_mul(a: u8, b: u8) -> u8 {
    if a == 0 || b == 0 {
        0
    } else {
        GF.0[GF.1[a as usize] as usize + GF.1[b as usize] as usize]
    }
}

#[inline]
fn gf_inv(a: u8) -> u8 {
    debug_assert!(a != 0, "gf_inv(0)");
    GF.0[255 - GF.1[a as usize] as usize]
}

/// `acc[i] ^= coeff * src[i]` for every byte, via a per-coefficient
/// product table (one table build amortized over the whole strip).
fn gf_mul_acc(acc: &mut [u8], coeff: u8, src: &[u8]) {
    debug_assert_eq!(acc.len(), src.len());
    if coeff == 0 {
        return;
    }
    if coeff == 1 {
        for (a, s) in acc.iter_mut().zip(src) {
            *a ^= s;
        }
        return;
    }
    let mut tbl = [0u8; 256];
    for (b, t) in tbl.iter_mut().enumerate() {
        *t = gf_mul(coeff, b as u8);
    }
    for (a, s) in acc.iter_mut().zip(src) {
        *a ^= tbl[*s as usize];
    }
}

/// Invert a k×k matrix over GF(2^8) by Gauss–Jordan elimination.
/// Errors if the matrix is singular (cannot happen for submatrices the
/// Cauchy construction yields, but the decoder checks anyway).
fn gf_invert(mut mat: Vec<Vec<u8>>) -> Result<Vec<Vec<u8>>> {
    let n = mat.len();
    let mut inv: Vec<Vec<u8>> = (0..n)
        .map(|i| {
            let mut row = vec![0u8; n];
            row[i] = 1;
            row
        })
        .collect();
    for col in 0..n {
        let pivot = (col..n).find(|&r| mat[r][col] != 0).ok_or_else(|| {
            Error::Integrity(format!("erasure: singular {n}x{n} decode matrix at column {col}"))
        })?;
        mat.swap(col, pivot);
        inv.swap(col, pivot);
        let scale = gf_inv(mat[col][col]);
        for v in mat[col].iter_mut().chain(inv[col].iter_mut()) {
            *v = gf_mul(*v, scale);
        }
        for row in 0..n {
            if row == col || mat[row][col] == 0 {
                continue;
            }
            let factor = mat[row][col];
            for c in 0..n {
                let (mv, iv) = (mat[col][c], inv[col][c]);
                mat[row][c] ^= gf_mul(factor, mv);
                inv[row][c] ^= gf_mul(factor, iv);
            }
        }
    }
    Ok(inv)
}

// ---------------------------------------------------------------------------
// Reed–Solomon codec.
// ---------------------------------------------------------------------------

/// Systematic RS(k, m) over GF(2^8): shards 0..k carry the payload
/// verbatim, shards k..k+m carry parity rows of a Cauchy matrix
/// (`parity[i][j] = 1 / ((k+i) ^ j)` — the x/y point sets are disjoint,
/// so every k×k submatrix of the stacked generator is invertible and
/// any k surviving shards reconstruct the payload).
#[derive(Debug, Clone)]
pub struct ReedSolomon {
    k: usize,
    m: usize,
    parity: Vec<Vec<u8>>,
}

impl ReedSolomon {
    /// Errors unless `1 ≤ k`, `1 ≤ m`, and `k + m ≤ 256` (GF(2^8) has
    /// only 256 distinct Cauchy points).
    pub fn new(k: usize, m: usize) -> Result<Self> {
        if k == 0 || m == 0 || k + m > 256 {
            return Err(Error::config(format!(
                "erasure: RS(k={k}, m={m}) needs 1 <= k, 1 <= m, k + m <= 256"
            )));
        }
        let parity = (0..m)
            .map(|i| (0..k).map(|j| gf_inv(((k + i) as u8) ^ (j as u8))).collect())
            .collect();
        Ok(Self { k, m, parity })
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn m(&self) -> usize {
        self.m
    }

    /// Compute the m parity shards for k equal-width data shards.
    pub fn encode(&self, data: &[&[u8]]) -> Result<Vec<Vec<u8>>> {
        if data.len() != self.k {
            return Err(Error::config(format!(
                "erasure: encode got {} data shards, expected k={}",
                data.len(),
                self.k
            )));
        }
        let width = data[0].len();
        if data.iter().any(|d| d.len() != width) {
            return Err(Error::config("erasure: encode shards differ in width".to_string()));
        }
        let mut parity = vec![vec![0u8; width]; self.m];
        for (p, row) in parity.iter_mut().zip(&self.parity) {
            for (j, d) in data.iter().enumerate() {
                gf_mul_acc(p, row[j], d);
            }
        }
        Ok(parity)
    }

    /// Rebuild every missing shard in place. `shards` must hold k+m
    /// slots (index order: data 0..k, parity k..k+m); present shards
    /// must agree on width. Errors loudly when fewer than k survive.
    pub fn reconstruct(&self, shards: &mut [Option<Vec<u8>>]) -> Result<()> {
        let n = self.k + self.m;
        if shards.len() != n {
            return Err(Error::config(format!(
                "erasure: reconstruct got {} shard slots, expected k+m={n}",
                shards.len()
            )));
        }
        let present: Vec<usize> = (0..n).filter(|&i| shards[i].is_some()).collect();
        if present.len() < self.k {
            return Err(Error::Integrity(format!(
                "erasure: need k={} shards to reconstruct, only {} survive",
                self.k,
                present.len()
            )));
        }
        let width = shards[present[0]].as_ref().map(|s| s.len()).unwrap_or(0);
        if present.iter().any(|&i| shards[i].as_ref().map(|s| s.len()) != Some(width)) {
            return Err(Error::Integrity(
                "erasure: surviving shards differ in width".to_string(),
            ));
        }
        if present.len() == n {
            return Ok(());
        }
        // Decode the k data shards from the first k survivors: invert
        // the k×k generator submatrix those survivors select.
        let chosen = &present[..self.k];
        if chosen.iter().any(|&i| i >= self.k) {
            let rows: Vec<Vec<u8>> = chosen
                .iter()
                .map(|&i| {
                    if i < self.k {
                        let mut row = vec![0u8; self.k];
                        row[i] = 1;
                        row
                    } else {
                        self.parity[i - self.k].clone()
                    }
                })
                .collect();
            let inv = gf_invert(rows)?;
            for d in 0..self.k {
                if shards[d].is_some() {
                    continue;
                }
                let mut out = vec![0u8; width];
                for (r, &src_idx) in chosen.iter().enumerate() {
                    let src = shards[src_idx].as_ref().expect("chosen shard present");
                    gf_mul_acc(&mut out, inv[d][r], src);
                }
                shards[d] = Some(out);
            }
        }
        // All data shards now present: recompute any missing parity.
        for p in 0..self.m {
            if shards[self.k + p].is_some() {
                continue;
            }
            let mut out = vec![0u8; width];
            for j in 0..self.k {
                let src = shards[j].as_ref().expect("data shard present");
                gf_mul_acc(&mut out, self.parity[p][j], src);
            }
            shards[self.k + p] = Some(out);
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Knobs.
// ---------------------------------------------------------------------------

/// `[erasure]` knobs (see `configs/polaris.toml`): the RS geometry, the
/// strip alignment quantum, the modeled encode throughput, and the
/// holder-placement policy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ErasureParams {
    /// Data strips per step. The payload ships as k strips of
    /// ceil(payload / k) bytes (alignment-padded).
    pub k: usize,
    /// Parity strips per step — the number of simultaneous holder
    /// losses a step survives.
    pub m: usize,
    /// Strip width quantum: widths round up to a multiple of this (and
    /// of [`DIRECT_IO_ALIGN`]), keeping strip files O_DIRECT-clean.
    pub strip_bytes: u64,
    /// Modeled GF(2^8) encode throughput (bytes/s of payload) charged
    /// as [`PlanOp::CpuWork`] on the simulated encode pump.
    pub encode_bw: f64,
    /// How the k+m holders are chosen over the topology. Like
    /// `ReplicaTier`, placement refuses rather than degrades when the
    /// topology cannot host k+m strips outside the owner's domain.
    pub policy: PlacementPolicy,
}

impl Default for ErasureParams {
    fn default() -> Self {
        Self {
            k: 4,
            m: 2,
            strip_bytes: MIB,
            encode_bw: 3.0e9,
            policy: PlacementPolicy::FailureDomainAware,
        }
    }
}

impl ErasureParams {
    /// Normalize: k/m floored at one, strip quantum up to an alignment
    /// multiple, encode bandwidth floored at a sane positive rate.
    pub fn normalized(mut self) -> Self {
        self.k = self.k.max(1);
        self.m = self.m.max(1);
        self.strip_bytes = align_up(self.strip_bytes.max(1), DIRECT_IO_ALIGN);
        if !(self.encode_bw > 1.0) {
            self.encode_bw = 1.0;
        }
        self
    }

    /// Read the `[erasure]` knobs out of a site config (e.g.
    /// `rust/configs/polaris.toml`); unspecified keys keep the
    /// defaults.
    pub fn from_toml(text: &str) -> std::result::Result<Self, String> {
        use crate::util::bytes::parse_bytes;
        use crate::util::toml::TomlDoc;
        let doc = TomlDoc::parse(text)?;
        let mut p = Self::default();
        if let Some(v) = doc.get_int("erasure.k") {
            p.k = v.max(1) as usize;
        }
        if let Some(v) = doc.get_int("erasure.m") {
            p.m = v.max(1) as usize;
        }
        if let Some(v) = doc.get_str("erasure.strip_bytes") {
            p.strip_bytes = parse_bytes(v)?;
        } else if let Some(v) = doc.get_int("erasure.strip_bytes") {
            p.strip_bytes = v.max(1) as u64;
        }
        if let Some(v) = doc.get_float("erasure.encode_bw") {
            p.encode_bw = v;
        }
        if let Some(v) = doc.get_str("erasure.policy") {
            p.policy = match v {
                "failure_domain" => PlacementPolicy::FailureDomainAware,
                "buddy_ring" => PlacementPolicy::BuddyRing,
                other => {
                    return Err(format!(
                        "erasure.policy: unknown policy {other:?} (expected \
                         \"failure_domain\" or \"buddy_ring\")"
                    ))
                }
            };
        }
        Ok(p.normalized())
    }
}

// ---------------------------------------------------------------------------
// Stripe planning.
// ---------------------------------------------------------------------------

/// Cuts a step's concatenated payload into k equal, alignment-clean,
/// zero-padded strips.
#[derive(Debug, Clone, Copy)]
pub struct StripePlanner {
    k: usize,
    quantum: u64,
}

impl StripePlanner {
    pub fn new(k: usize, quantum: u64) -> Self {
        Self {
            k: k.max(1),
            quantum: align_up(quantum.max(1), DIRECT_IO_ALIGN),
        }
    }

    /// Width of each strip for a payload: ceil(payload / k) rounded up
    /// to the quantum (never zero, so even empty payloads commit real
    /// strip files the decoder can width-check).
    pub fn strip_width(&self, payload: u64) -> u64 {
        align_up(payload.div_ceil(self.k as u64).max(1), self.quantum)
    }

    /// Split the payload into k strips of `strip_width` bytes, the
    /// tail zero-padded.
    pub fn split(&self, payload: &[u8]) -> Vec<Vec<u8>> {
        let width = self.strip_width(payload.len() as u64) as usize;
        (0..self.k)
            .map(|i| {
                let lo = (i * width).min(payload.len());
                let hi = ((i + 1) * width).min(payload.len());
                let mut strip = payload[lo..hi].to_vec();
                strip.resize(width, 0);
                strip
            })
            .collect()
    }
}

// ---------------------------------------------------------------------------
// Per-strip header.
// ---------------------------------------------------------------------------

/// Stored beside every strip (`stripe.json`): the stripe geometry plus
/// the original blob inventory (paths, lengths, CRCs from the source
/// manifest), so any k strips alone re-materialize and *verify* the
/// step without consulting the owner.
pub const STRIPE_HEADER_FILE: &str = "stripe.json";

#[derive(Debug, Clone, PartialEq)]
pub struct StripeHeader {
    /// Node whose checkpoint this stripe encodes.
    pub owner: usize,
    pub step: u64,
    pub k: usize,
    pub m: usize,
    /// Which strip of the stripe this copy is (0..k data, k..k+m parity).
    pub index: usize,
    /// Strip width in bytes (equal across the stripe).
    pub width: u64,
    /// Concatenated payload length before padding.
    pub payload_bytes: u64,
    /// The source step's blob inventory, in concatenation order.
    pub files: Vec<ManifestFile>,
}

impl StripeHeader {
    /// True when `other` describes the same stripe (all geometry equal,
    /// only the strip index may differ).
    pub fn compatible(&self, other: &StripeHeader) -> bool {
        self.owner == other.owner
            && self.step == other.step
            && self.k == other.k
            && self.m == other.m
            && self.width == other.width
            && self.payload_bytes == other.payload_bytes
            && self.files == other.files
    }

    fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut doc = Json::obj();
        doc.set("owner", self.owner)
            .set("step", self.step)
            .set("k", self.k as u64)
            .set("m", self.m as u64)
            .set("index", self.index as u64)
            .set("width", self.width)
            .set("payload_bytes", self.payload_bytes);
        let mut files = Vec::new();
        for f in &self.files {
            let mut doc = Json::obj();
            doc.set("path", f.path.as_str())
                .set("len", f.len)
                .set("crc", f.crc as u64);
            files.push(doc);
        }
        doc.set("files", Json::Arr(files));
        doc
    }

    fn from_json(doc: &crate::util::json::Json) -> Result<Self> {
        use crate::util::json::Json;
        let get_u64 = |key: &str| -> Result<u64> {
            doc.get(key)
                .and_then(Json::as_u64)
                .ok_or_else(|| Error::Format(format!("stripe header: missing {key}")))
        };
        let files = doc
            .get("files")
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::Format("stripe header: missing files".to_string()))?
            .iter()
            .map(|f| {
                let path = f
                    .get("path")
                    .and_then(Json::as_str)
                    .ok_or_else(|| Error::Format("stripe header: file missing path".to_string()))?;
                let len = f
                    .get("len")
                    .and_then(Json::as_u64)
                    .ok_or_else(|| Error::Format("stripe header: file missing len".to_string()))?;
                let crc = f
                    .get("crc")
                    .and_then(Json::as_u64)
                    .ok_or_else(|| Error::Format("stripe header: file missing crc".to_string()))?;
                Ok(ManifestFile {
                    path: path.to_string(),
                    len,
                    crc: crc as u32,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Self {
            owner: get_u64("owner")? as usize,
            step: get_u64("step")?,
            k: get_u64("k")? as usize,
            m: get_u64("m")? as usize,
            index: get_u64("index")? as usize,
            width: get_u64("width")?,
            payload_bytes: get_u64("payload_bytes")?,
            files,
        })
    }

    /// Write + fsync the header into a strip directory. A plain data
    /// file: the strip's [`TierManifest`] commit afterwards covers it
    /// with a CRC like any other blob.
    pub fn save(&self, dir: &Path) -> Result<()> {
        let path = dir.join(STRIPE_HEADER_FILE);
        let mut fh = fs::File::create(&path)?;
        fh.write_all(self.to_json().to_pretty().as_bytes())?;
        fh.sync_all()?;
        Ok(())
    }

    pub fn load(dir: &Path) -> Result<Self> {
        let text = fs::read_to_string(dir.join(STRIPE_HEADER_FILE))?;
        let doc = crate::util::json::Json::parse(&text).map_err(Error::Format)?;
        Self::from_json(&doc)
    }
}

// ---------------------------------------------------------------------------
// Events, reports, tier state.
// ---------------------------------------------------------------------------

/// Observable erasure-tier lifecycle events (ordering assertions in
/// tests: strip data is always synced before its commit).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErasureEvent {
    /// Strip bytes + header written and fsynced at `holder`.
    StripSynced { holder: usize, step: u64, index: usize },
    /// Strip manifest committed at `holder` (temp+rename done).
    StripCommitted { holder: usize, step: u64, index: usize },
    /// Strip evicted from `holder` under budget pressure.
    StripEvicted { holder: usize, step: u64, index: usize },
}

/// What one [`ErasureTier::encode_and_distribute`] call achieved.
#[derive(Debug, Clone)]
pub struct ErasureReport {
    pub step: u64,
    /// Concatenated payload length before padding.
    pub payload_bytes: u64,
    /// Width of each strip (alignment-padded).
    pub strip_width: u64,
    /// Total parity bytes shipped (`m * strip_width`).
    pub parity_bytes: u64,
    /// `(strip index, holder)` pairs that committed.
    pub acked: Vec<(usize, usize)>,
    /// Per-strip failures (non-fatal while ≥ k strips committed —
    /// the step restores, but is *unprotected* until re-encoded).
    pub errors: Vec<String>,
}

#[derive(Debug, Default)]
struct ErasureState {
    /// step -> strip index -> holder node (committed strips only).
    committed: BTreeMap<u64, BTreeMap<usize, usize>>,
    /// (holder, step) -> bytes charged against the holder's budget.
    sizes: BTreeMap<(usize, u64), u64>,
    /// holder -> bytes used (reservations included).
    used: BTreeMap<usize, u64>,
    /// Steps with encode enqueued but not finished.
    pending: BTreeSet<u64>,
    /// Steps whose last encode left fewer than k+m strips committed
    /// (restorable if ≥ k, but with less than the configured margin).
    failed: BTreeSet<u64>,
    events: Vec<ErasureEvent>,
    evictions: u64,
    degraded_restores: u64,
    /// (owner, step) -> cached reconstruction: the materialized
    /// directory plus the surviving-strip count and degraded flag of
    /// the decode that produced it (decode is expensive; delta
    /// ancestor walks may ask for the same step repeatedly).
    materialized: BTreeMap<(usize, u64), (PathBuf, usize, bool)>,
}

fn strip_filename(index: usize) -> String {
    format!("strip_{index}.bin")
}

// ---------------------------------------------------------------------------
// The tier.
// ---------------------------------------------------------------------------

/// The real-storage erasure strip store. Layout mirrors `ReplicaTier`
/// (`node{holder}/from_node{owner}/step_*`), with each step directory
/// holding exactly one strip file, its [`StripeHeader`], and the
/// [`TierManifest`] commit.
pub struct ErasureTier {
    topo: Topology,
    params: ErasureParams,
    rs: ReedSolomon,
    planner: StripePlanner,
    node: usize,
    /// `holders[i]` stores strip `i` (k+m entries, each in a distinct
    /// foreign failure domain under the default policy).
    holders: Vec<usize>,
    root: PathBuf,
    capacity_per_node: u64,
    backend: BackendKind,
    state: Mutex<ErasureState>,
    /// Shared copies registry (attached by the cascade): eviction
    /// decisions read PFS-durability under its lock, and every strip
    /// commit/drop is mirrored into its strip accounting.
    registry: Option<Arc<CopiesRegistry>>,
}

impl ErasureTier {
    /// An erasure tier for `node`'s rank group, striping into the k+m
    /// holders `params.policy` selects over `topo`. Existing committed
    /// strip directories under `root` (from `node`) are recovered into
    /// the accounting — the crash-restart path. Errors when the
    /// topology cannot host k+m strips outside `node`'s domain.
    pub fn new(
        root: impl Into<PathBuf>,
        topo: Topology,
        node: usize,
        params: ErasureParams,
    ) -> Result<Self> {
        let params = params.normalized();
        let rs = ReedSolomon::new(params.k, params.m)?;
        let holders = params.policy.buddies_of(&topo, node, params.k + params.m)?;
        let root = root.into();
        fs::create_dir_all(&root)?;
        let planner = StripePlanner::new(params.k, params.strip_bytes);
        let mut state = ErasureState::default();
        for &holder in &holders {
            let dir = root.join(format!("node{holder}")).join(format!("from_node{node}"));
            let entries = match fs::read_dir(&dir) {
                Ok(e) => e,
                Err(_) => continue, // no strips there yet
            };
            for entry in entries {
                let entry = entry?;
                let p = entry.path();
                if !p.is_dir() {
                    continue;
                }
                let name = entry.file_name().to_string_lossy().into_owned();
                if let Some(step) = parse_step_dirname(&name) {
                    // Only committed strips count; uncommitted crash
                    // remains are invisible (clobbered on re-encode).
                    let m = match TierManifest::load(&p) {
                        Ok(m) if m.step == step => m,
                        _ => continue,
                    };
                    let hdr = match StripeHeader::load(&p) {
                        Ok(h) => h,
                        Err(_) => continue,
                    };
                    // A geometry change across restarts orphans old
                    // strips; don't mix them into the new stripe map.
                    if hdr.k != params.k || hdr.m != params.m || hdr.owner != node {
                        continue;
                    }
                    let bytes = m.payload_bytes();
                    state.committed.entry(step).or_default().insert(hdr.index, holder);
                    state.sizes.insert((holder, step), bytes);
                    *state.used.entry(holder).or_insert(0) += bytes;
                }
            }
        }
        Ok(Self {
            topo,
            params,
            rs,
            planner,
            node,
            holders,
            root,
            capacity_per_node: u64::MAX,
            backend: BackendKind::Posix,
            state: Mutex::new(state),
            registry: None,
        })
    }

    /// Per-holder strip budget in bytes (`u64::MAX` = unbounded).
    /// Covers this owner's strips at each holder.
    pub fn with_capacity_per_node(mut self, bytes: u64) -> Self {
        self.capacity_per_node = bytes.max(1);
        self
    }

    pub fn with_registry(mut self, registry: Arc<CopiesRegistry>) -> Self {
        {
            // Registry strictly before the component lock.
            let mut reg = registry.lock();
            let st = self.state.lock().unwrap();
            for (step, strips) in &st.committed {
                for &holder in strips.values() {
                    reg.record_strip(holder, *step, self.params.k);
                }
            }
        }
        self.registry = Some(registry);
        self
    }

    pub fn with_backend(mut self, backend: BackendKind) -> Self {
        self.backend = backend;
        self
    }

    /// The node whose checkpoints this tier stripes out.
    pub fn node(&self) -> usize {
        self.node
    }

    pub fn params(&self) -> ErasureParams {
        self.params
    }

    /// `holders()[i]` stores strip `i`.
    pub fn holders(&self) -> &[usize] {
        &self.holders
    }

    fn node_dir(&self, holder: usize) -> PathBuf {
        self.root.join(format!("node{holder}"))
    }

    fn store_dir(&self, owner: usize, holder: usize, step: u64) -> PathBuf {
        self.node_dir(holder)
            .join(format!("from_node{owner}"))
            .join(step_dirname(step))
    }

    /// Record that an encode for `step` has been enqueued (the cascade
    /// marks this before handing the job to its pool, so eviction and
    /// resave guards see in-flight stripes).
    pub fn mark_pending(&self, step: u64) {
        self.state.lock().unwrap().pending.insert(step);
    }

    pub fn pending_steps(&self) -> BTreeSet<u64> {
        self.state.lock().unwrap().pending.clone()
    }

    /// Committed strips of `step` still on their holders.
    pub fn strip_count(&self, step: u64) -> usize {
        self.state
            .lock()
            .unwrap()
            .committed
            .get(&step)
            .map(|s| s.len())
            .unwrap_or(0)
    }

    /// True when ≥ k strips of `step` survive — the step restores.
    pub fn recoverable_at(&self, step: u64) -> bool {
        self.strip_count(step) >= self.params.k
    }

    /// Steps with ≥ k committed surviving strips.
    pub fn recoverable_steps(&self) -> BTreeSet<u64> {
        self.state
            .lock()
            .unwrap()
            .committed
            .iter()
            .filter(|(_, s)| s.len() >= self.params.k)
            .map(|(&step, _)| step)
            .collect()
    }

    pub fn latest_recoverable_step(&self) -> Option<u64> {
        self.recoverable_steps().into_iter().next_back()
    }

    /// Steps enqueued or unprotected — the encode lag a monitoring
    /// loop watches.
    pub fn replication_lag(&self) -> usize {
        let st = self.state.lock().unwrap();
        st.pending.len() + st.failed.len()
    }

    pub fn failed_steps(&self) -> BTreeSet<u64> {
        self.state.lock().unwrap().failed.clone()
    }

    pub fn used_bytes(&self, holder: usize) -> u64 {
        self.state.lock().unwrap().used.get(&holder).copied().unwrap_or(0)
    }

    pub fn events(&self) -> Vec<ErasureEvent> {
        self.state.lock().unwrap().events.clone()
    }

    pub fn eviction_count(&self) -> u64 {
        self.state.lock().unwrap().evictions
    }

    pub fn degraded_restore_count(&self) -> u64 {
        self.state.lock().unwrap().degraded_restores
    }

    /// Encode `step`'s committed blobs (per `manifest`, read out of
    /// `src_dir`) into k data + m parity strips and commit one per
    /// holder. Crash-consistent per strip: strip bytes + header are
    /// fsynced strictly before the strip's manifest temp+rename, so a
    /// crash mid-commit leaves at most an uncommitted (invisible)
    /// directory. Errors when fewer than k strips commit — the step
    /// would not be restorable from this tier; with k..k+m-1 commits
    /// it succeeds but the step joins [`ErasureTier::failed_steps`]
    /// (restorable, yet below the configured loss margin).
    pub fn encode_and_distribute(
        &self,
        step: u64,
        src_dir: &Path,
        manifest: &TierManifest,
        durable_elsewhere: &[u64],
    ) -> Result<ErasureReport> {
        // Concatenate the step's blobs in manifest order.
        let mut payload = Vec::with_capacity(manifest.payload_bytes() as usize);
        for f in &manifest.files {
            let bytes = fs::read(src_dir.join(&f.path))?;
            if bytes.len() as u64 != f.len {
                return Err(Error::Integrity(format!(
                    "erasure: {} is {} bytes, manifest says {}",
                    f.path,
                    bytes.len(),
                    f.len
                )));
            }
            payload.extend_from_slice(&bytes);
        }
        let payload_bytes = payload.len() as u64;
        let width = self.planner.strip_width(payload_bytes);
        let data = self.planner.split(&payload);
        drop(payload);
        let refs: Vec<&[u8]> = data.iter().map(|d| d.as_slice()).collect();
        let parity = self.rs.encode(&refs)?;
        let shards: Vec<&[u8]> = data
            .iter()
            .chain(parity.iter())
            .map(|v| v.as_slice())
            .collect();

        // Drop any stale incarnation of this step — accounting and
        // registry mirror together — before reserving: a failure below
        // then leaves neither phantom byte counts nor strips a decode
        // could mix with the new stripe.
        {
            let mut reg = self.registry.as_ref().map(|r| r.lock());
            let mut st = self.state.lock().unwrap();
            if let Some(old) = st.committed.remove(&step) {
                for &holder in old.values() {
                    if let Some(b) = st.sizes.remove(&(holder, step)) {
                        if let Some(u) = st.used.get_mut(&holder) {
                            *u = u.saturating_sub(b);
                        }
                    }
                    if let Some(reg) = reg.as_mut() {
                        reg.drop_strip(holder, step);
                    }
                }
            }
            st.materialized.remove(&(self.node, step));
        }
        let _ = fs::remove_dir_all(self.reconstructed_dir(self.node, step));

        let mut acked = Vec::new();
        let mut errors = Vec::new();
        for (idx, shard) in shards.iter().enumerate() {
            let holder = self.holders[idx];
            let res = (|| -> Result<()> {
                let dst = self.store_dir(self.node, holder, step);
                let _ = fs::remove_dir_all(&dst); // stale/crash remains
                // Reserve the strip against the holder's budget before
                // moving data (single-acquisition capacity check, as
                // the replica tier).
                self.reserve_room(holder, step, width, durable_elsewhere)?;
                let written = (|| -> Result<()> {
                    fs::create_dir_all(&dst)?;
                    let path = dst.join(strip_filename(idx));
                    let mut fh = fs::File::create(&path)?;
                    fh.write_all(shard)?;
                    fh.sync_all()?;
                    StripeHeader {
                        owner: self.node,
                        step,
                        k: self.params.k,
                        m: self.params.m,
                        index: idx,
                        width,
                        payload_bytes,
                        files: manifest.files.clone(),
                    }
                    .save(&dst)?;
                    self.state.lock().unwrap().events.push(ErasureEvent::StripSynced {
                        holder,
                        step,
                        index: idx,
                    });
                    TierManifest::from_dir(step, &dst)?
                        .with_replica_of(Some(self.node))
                        .commit(&dst)?;
                    Ok(())
                })();
                let mut reg = self.registry.as_ref().map(|r| r.lock());
                let mut st = self.state.lock().unwrap();
                match written {
                    Ok(()) => {
                        st.events.push(ErasureEvent::StripCommitted {
                            holder,
                            step,
                            index: idx,
                        });
                        st.committed.entry(step).or_default().insert(idx, holder);
                        // `used` already carries the reservation.
                        st.sizes.insert((holder, step), width);
                        if let Some(reg) = reg.as_mut() {
                            reg.record_strip(holder, step, self.params.k);
                        }
                        Ok(())
                    }
                    Err(e) => {
                        // Release the reservation of the failed strip.
                        if let Some(u) = st.used.get_mut(&holder) {
                            *u = u.saturating_sub(width);
                        }
                        Err(e)
                    }
                }
            })();
            match res {
                Ok(()) => acked.push((idx, holder)),
                Err(e) => errors.push(format!("strip {idx} at node {holder}: {e}")),
            }
        }
        {
            let mut st = self.state.lock().unwrap();
            st.pending.remove(&step);
            if acked.len() < self.params.k + self.params.m {
                st.failed.insert(step);
            } else {
                st.failed.remove(&step);
            }
        }
        if acked.len() < self.params.k {
            return Err(Error::msg(format!(
                "step {step}: only {} of {} strips committed (need k={} to restore): {}",
                acked.len(),
                self.params.k + self.params.m,
                self.params.k,
                errors.join("; ")
            )));
        }
        Ok(ErasureReport {
            step,
            payload_bytes,
            strip_width: width,
            parity_bytes: self.params.m as u64 * width,
            acked,
            errors,
        })
    }

    /// Evict this owner's strips from `holder` until `incoming` more
    /// bytes fit its budget, then **reserve** those bytes (single lock
    /// acquisition — concurrent encodes never jointly overshoot).
    /// Victims must be strictly older than the incoming step and
    /// either durable on the slowest tier or left with **more than k**
    /// strips after the eviction — a step never drops below k
    /// reachable strips unless the PFS already holds it.
    fn reserve_room(
        &self,
        holder: usize,
        step: u64,
        incoming: u64,
        durable_elsewhere: &[u64],
    ) -> Result<()> {
        // Header + manifest sidecar slack (strips are whole files, so
        // the margin is smaller than the cascade's store padding).
        let need = incoming + incoming / 8 + (1 << 16);
        let k = self.params.k;
        let slowest = self.registry.as_ref().map(|r| r.slowest_tier());
        let mut reg = self.registry.as_ref().map(|r| r.lock());
        // Victim directories renamed aside by `evict`, deleted only
        // after the registry lock drops (the single-lock protocol).
        let mut doomed: Vec<PathBuf> = Vec::new();
        let outcome = loop {
            let decision = {
                let mut st = self.state.lock().unwrap();
                let used = st.used.get(&holder).copied().unwrap_or(0);
                if self.capacity_per_node == u64::MAX
                    || used.saturating_add(need) <= self.capacity_per_node
                {
                    *st.used.entry(holder).or_insert(0) += incoming;
                    None
                } else {
                    let candidate = st
                        .sizes
                        .keys()
                        .filter(|(h, _)| *h == holder)
                        .map(|&(_, s)| s)
                        .find(|s| {
                            if *s >= step {
                                return false;
                            }
                            let durable = match (&reg, slowest) {
                                // A single-tier cascade's slowest tier
                                // is the node's own burst buffer —
                                // nothing is durable through it.
                                (Some(copies), Some(t)) => t > 0 && copies.durable_at(t, *s),
                                _ => durable_elsewhere.contains(s),
                            };
                            let spare_strips = st
                                .committed
                                .get(s)
                                .map(|strips| strips.len() > k)
                                .unwrap_or(false);
                            durable || spare_strips
                        });
                    Some(candidate)
                }
            };
            match decision {
                None => break Ok(()),
                Some(Some(v)) => match self.evict(holder, v, reg.as_deref_mut()) {
                    Ok(Some(tmp)) => doomed.push(tmp),
                    Ok(None) => {}
                    Err(e) => break Err(e),
                },
                Some(None) => {
                    break Err(Error::msg(format!(
                        "erasure store node{holder}: {need} bytes will not fit budget {}; \
                         no victim strip is older than step {step} and either durable on \
                         the PFS or above k={k} surviving strips",
                        self.capacity_per_node
                    )))
                }
            }
        };
        drop(reg);
        for tmp in doomed {
            let _ = fs::remove_dir_all(&tmp);
        }
        outcome
    }

    /// Drop this owner's strip of `step` at `holder`. `reg` is the
    /// already-held registry guard under the single-lock protocol. The
    /// victim directory is renamed aside (atomic, invisible to
    /// manifest loads and recovery scans) and returned for the caller
    /// to delete once the registry lock is released.
    fn evict(&self, holder: usize, step: u64, reg: Option<&mut Copies>) -> Result<Option<PathBuf>> {
        let dir = self.store_dir(self.node, holder, step);
        let doomed = if dir.exists() {
            let tmp = dir.with_extension("evicting");
            let _ = fs::remove_dir_all(&tmp); // stale remains
            fs::rename(&dir, &tmp)?;
            Some(tmp)
        } else {
            None
        };
        let mut st = self.state.lock().unwrap();
        if let Some(old) = st.sizes.remove(&(holder, step)) {
            if let Some(u) = st.used.get_mut(&holder) {
                *u = u.saturating_sub(old);
            }
        }
        let index = st
            .committed
            .get(&step)
            .and_then(|strips| strips.iter().find(|(_, h)| **h == holder).map(|(&i, _)| i));
        if let Some(i) = index {
            let emptied = st
                .committed
                .get_mut(&step)
                .map(|strips| {
                    strips.remove(&i);
                    strips.is_empty()
                })
                .unwrap_or(false);
            if emptied {
                st.committed.remove(&step);
            }
            st.events.push(ErasureEvent::StripEvicted {
                holder,
                step,
                index: i,
            });
        }
        st.evictions += 1;
        drop(st);
        if let Some(reg) = reg {
            reg.drop_strip(holder, step);
        }
        Ok(doomed)
    }

    /// A holder died: drop every strip it stored (directory and
    /// accounting, registry mirror included). Steps keep restoring
    /// while ≥ k strips survive elsewhere.
    pub fn fail_node(&self, node: usize) -> Result<()> {
        let _ = fs::remove_dir_all(self.node_dir(node));
        let mut reg = self.registry.as_ref().map(|r| r.lock());
        let mut st = self.state.lock().unwrap();
        let steps: Vec<u64> = st
            .sizes
            .keys()
            .filter(|(h, _)| *h == node)
            .map(|&(_, s)| s)
            .collect();
        for s in steps {
            if let Some(b) = st.sizes.remove(&(node, s)) {
                if let Some(u) = st.used.get_mut(&node) {
                    *u = u.saturating_sub(b);
                }
            }
            let emptied = st
                .committed
                .get_mut(&s)
                .map(|strips| {
                    strips.retain(|_, h| *h != node);
                    strips.is_empty()
                })
                .unwrap_or(false);
            if emptied {
                st.committed.remove(&s);
            }
            if let Some(reg) = reg.as_mut() {
                reg.drop_strip(node, s);
            }
        }
        st.used.remove(&node);
        Ok(())
    }

    fn reconstructed_dir(&self, owner: usize, step: u64) -> PathBuf {
        self.root
            .join("reconstructed")
            .join(format!("node{owner}"))
            .join(step_dirname(step))
    }

    /// Gather any k surviving strips of (`owner`, `step`), decode if a
    /// data strip is lost, and re-materialize the step's original
    /// blobs into a committed directory under the tier root. Returns
    /// the directory, the surviving-strip count, and whether the
    /// restore ran degraded (parity decoding was needed). Every
    /// re-materialized blob is verified against the CRC the header
    /// recorded at encode time — bit-identity, not best-effort. Errors
    /// loudly when fewer than k strips survive.
    pub fn reconstruct_dir(&self, owner: usize, step: u64) -> Result<(PathBuf, usize, bool)> {
        let k = self.params.k;
        let n = k + self.params.m;
        // Serve the cached materialization while it is still committed
        // (decode is expensive; delta ancestor walks repeat steps).
        {
            let st = self.state.lock().unwrap();
            if let Some((dir, survivors, degraded)) = st.materialized.get(&(owner, step)) {
                if TierManifest::load(dir).map(|m| m.step == step).unwrap_or(false) {
                    return Ok((dir.clone(), *survivors, *degraded));
                }
            }
        }
        let holders = if owner == self.node {
            self.holders.clone()
        } else {
            self.params.policy.buddies_of(&self.topo, owner, n)?
        };
        let mut shards: Vec<Option<Vec<u8>>> = vec![None; n];
        let mut proto: Option<StripeHeader> = None;
        for (idx, &holder) in holders.iter().enumerate() {
            let dir = self.store_dir(owner, holder, step);
            let m = match TierManifest::load(&dir) {
                Ok(m) if m.step == step => m,
                _ => continue,
            };
            if m.verify(&dir).is_err() {
                continue;
            }
            let hdr = match StripeHeader::load(&dir) {
                Ok(h) => h,
                Err(_) => continue,
            };
            if hdr.index != idx
                || hdr.k != k
                || hdr.m != self.params.m
                || hdr.owner != owner
                || hdr.step != step
            {
                continue;
            }
            if let Some(p) = &proto {
                if !p.compatible(&hdr) {
                    continue;
                }
            }
            let bytes = match fs::read(dir.join(strip_filename(idx))) {
                Ok(b) => b,
                Err(_) => continue,
            };
            if bytes.len() as u64 != hdr.width {
                continue;
            }
            if proto.is_none() {
                proto = Some(hdr);
            }
            shards[idx] = Some(bytes);
        }
        let survivors = shards.iter().filter(|s| s.is_some()).count();
        let hdr = proto.filter(|_| survivors >= k).ok_or_else(|| {
            Error::Integrity(format!(
                "erasure: step {step} of node {owner} needs k={k} strips to \
                 reconstruct, only {survivors} survive"
            ))
        })?;
        let degraded = shards[..k].iter().any(|s| s.is_none());
        if degraded {
            self.rs.reconstruct(&mut shards)?;
        }
        // Concatenate the data strips and cut the payload back out.
        let mut payload = Vec::with_capacity(k * hdr.width as usize);
        for s in shards.iter().take(k) {
            payload.extend_from_slice(s.as_ref().expect("data shard present"));
        }
        payload.truncate(hdr.payload_bytes as usize);
        // Re-materialize the original blobs, CRC-verified per file.
        let out = self.reconstructed_dir(owner, step);
        let _ = fs::remove_dir_all(&out);
        fs::create_dir_all(&out)?;
        let mut off = 0usize;
        for f in &hdr.files {
            let end = off + f.len as usize;
            if end > payload.len() {
                return Err(Error::Integrity(format!(
                    "erasure: stripe payload of step {step} too short for {}",
                    f.path
                )));
            }
            let blob = &payload[off..end];
            off = end;
            if crc32fast::hash(blob) != f.crc {
                return Err(Error::Integrity(format!(
                    "erasure: decoded {} of step {step} fails its CRC",
                    f.path
                )));
            }
            let path = out.join(&f.path);
            if let Some(parent) = path.parent() {
                fs::create_dir_all(parent)?;
            }
            let mut fh = fs::File::create(&path)?;
            fh.write_all(blob)?;
            fh.sync_all()?;
        }
        TierManifest::from_dir(step, &out)?
            .with_replica_of(Some(owner))
            .commit(&out)?;
        {
            let mut st = self.state.lock().unwrap();
            if degraded {
                st.degraded_restores += 1;
            }
            st.materialized
                .insert((owner, step), (out.clone(), survivors, degraded));
        }
        Ok((out, survivors, degraded))
    }

    /// Reconstruct and load this node's `step`.
    pub fn restore(&self, step: u64) -> Result<(Vec<RankData>, usize, bool)> {
        self.restore_node(self.node, step)
    }

    /// Reconstruct and load `owner`'s `step` — any node may decode any
    /// owner's stripe; strips and headers are self-describing.
    pub fn restore_node(&self, owner: usize, step: u64) -> Result<(Vec<RankData>, usize, bool)> {
        let (dir, survivors, degraded) = self.reconstruct_dir(owner, step)?;
        let data = CheckpointStore::new(&dir).with_backend(self.backend).load()?;
        Ok((data, survivors, degraded))
    }
}

/// Transform a burst-buffer-targeted checkpoint plan into its erasure
/// encode+distribute plan: read each written extent back from the
/// local tier, pay the GF(2^8) encode CPU cost ([`PlanOp::CpuWork`] at
/// `params.encode_bw`), then push one width-wide strip to each
/// holder's `peer/n{h}/…` store. The strip writes route over the
/// per-node peer fabric *and* the node's NIC egress port, so the
/// (k+m)/k redundancy traffic contends with PFS flushes exactly where
/// replication's does — `fig27_erasure` sweeps RS(k, m) against
/// fan-out-f buddy replication on this model. Pair with
/// [`crate::tier::model::writeback_drain_plan`] under
/// [`crate::simpfs::exec::SimExecutor::with_background_drains`].
pub fn erasure_drain_plan(plan: &RankPlan, holders: &[usize], params: &ErasureParams) -> RankPlan {
    let params = params.normalized();
    let planner = StripePlanner::new(params.k, params.strip_bytes);
    let payload = plan.write_bytes();
    let width = planner.strip_width(payload);
    let mut out = RankPlan::new(plan.rank, plan.node);
    let n_src = plan.files.len();
    for spec in &plan.files {
        out.add_file(FileSpec {
            path: spec.path.clone(),
            direct: spec.direct,
            size_hint: 0,
            creates: false,
        });
    }
    for (j, &h) in holders.iter().enumerate() {
        out.add_file(FileSpec {
            path: peer_path(h, &format!("ec/from_node{}/{}", plan.node, strip_filename(j))),
            direct: true,
            size_hint: width,
            creates: true,
        });
    }
    for f in 0..n_src {
        out.push(PlanOp::Open { file: f });
    }
    for j in 0..holders.len() {
        out.push(PlanOp::Create { file: n_src + j });
    }
    for op in &plan.ops {
        if let PlanOp::Write { file, offset, src } = op {
            out.push(PlanOp::Read {
                file: *file,
                offset: *offset,
                dst: *src,
            });
        }
    }
    out.push(PlanOp::Drain);
    let us = ((payload as f64 / params.encode_bw) * 1e6).ceil() as u64;
    out.push(PlanOp::CpuWork { us: us.max(1) });
    for j in 0..holders.len() {
        out.push(PlanOp::Write {
            file: n_src + j,
            offset: 0,
            src: BufSlice::new(0, width),
        });
    }
    out.push(PlanOp::Drain);
    for j in 0..holders.len() {
        out.push(PlanOp::Fsync { file: n_src + j });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ckpt::lean;
    use crate::ckpt::store::CheckpointStore;
    use crate::util::prng::Xoshiro256;

    fn tmp(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "ckptio-erasure-{name}-{}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    fn data(rank: usize, bytes: usize, seed: u64) -> RankData {
        let mut rng = Xoshiro256::seeded(seed ^ rank as u64);
        let mut buf = vec![0u8; bytes];
        rng.fill_bytes(&mut buf);
        RankData {
            rank,
            tensors: vec![("w".into(), buf)],
            lean: lean::training_state(seed, 1e-3, "erasure"),
        }
    }

    /// Bit-identity across a restore: ranks and tensor bytes match.
    fn assert_bit_identical(a: &[RankData], b: &[RankData]) {
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            assert_eq!(x.rank, y.rank);
            assert_eq!(x.tensors, y.tensors);
        }
    }

    /// Save a committed source step under `dir` and return its manifest.
    fn source_step(dir: &Path, step: u64, bytes: usize) -> TierManifest {
        let shards = vec![data(0, bytes, step), data(1, bytes, step + 7)];
        CheckpointStore::new(dir).save(&shards).unwrap();
        let m = TierManifest::from_dir(step, dir).unwrap();
        m.clone().commit(dir).unwrap();
        m
    }

    #[test]
    fn gf_math_identities() {
        // Multiplicative identities and inverses across the field.
        for a in 1..=255u8 {
            assert_eq!(gf_mul(a, 1), a);
            assert_eq!(gf_mul(1, a), a);
            assert_eq!(gf_mul(a, 0), 0);
            assert_eq!(gf_mul(a, gf_inv(a)), 1, "a={a}");
        }
        assert_eq!(gf_inv(1), 1);
        // Commutativity + associativity spot checks.
        let mut rng = Xoshiro256::seeded(42);
        for _ in 0..200 {
            let (a, b, c) = (
                rng.next_u64() as u8,
                rng.next_u64() as u8,
                rng.next_u64() as u8,
            );
            assert_eq!(gf_mul(a, b), gf_mul(b, a));
            assert_eq!(gf_mul(gf_mul(a, b), c), gf_mul(a, gf_mul(b, c)));
            // Distributivity over XOR (field addition).
            assert_eq!(gf_mul(a, b ^ c), gf_mul(a, b) ^ gf_mul(a, c));
        }
    }

    #[test]
    fn rs_roundtrips_every_loss_pattern() {
        // RS(4, 2): every way of losing ≤ m = 2 of the 6 shards must
        // reconstruct bit-identically (all C(6,2) + C(6,1) + 1 = 22
        // patterns, exhaustively).
        let rs = ReedSolomon::new(4, 2).unwrap();
        let mut rng = Xoshiro256::seeded(7);
        let width = 257; // deliberately odd
        let data: Vec<Vec<u8>> = (0..4)
            .map(|_| (0..width).map(|_| rng.next_u64() as u8).collect())
            .collect();
        let refs: Vec<&[u8]> = data.iter().map(|d| d.as_slice()).collect();
        let parity = rs.encode(&refs).unwrap();
        let full: Vec<Vec<u8>> = data.iter().chain(parity.iter()).cloned().collect();
        let mut patterns: Vec<Vec<usize>> = vec![vec![]];
        patterns.extend((0..6).map(|i| vec![i]));
        for i in 0..6 {
            for j in (i + 1)..6 {
                patterns.push(vec![i, j]);
            }
        }
        assert_eq!(patterns.len(), 22);
        for lost in patterns {
            let mut shards: Vec<Option<Vec<u8>>> = full.iter().cloned().map(Some).collect();
            for &i in &lost {
                shards[i] = None;
            }
            rs.reconstruct(&mut shards).unwrap();
            for (i, s) in shards.iter().enumerate() {
                assert_eq!(s.as_deref(), Some(full[i].as_slice()), "lost={lost:?} shard={i}");
            }
        }
    }

    #[test]
    fn rs_fails_loudly_below_k() {
        let rs = ReedSolomon::new(3, 2).unwrap();
        let data: Vec<Vec<u8>> = (0..3).map(|i| vec![i as u8; 16]).collect();
        let refs: Vec<&[u8]> = data.iter().map(|d| d.as_slice()).collect();
        let parity = rs.encode(&refs).unwrap();
        let mut shards: Vec<Option<Vec<u8>>> =
            data.iter().chain(parity.iter()).cloned().map(Some).collect();
        // Lose m + 1 = 3 shards: only k - 1 survive.
        shards[0] = None;
        shards[2] = None;
        shards[4] = None;
        let err = rs.reconstruct(&mut shards).unwrap_err().to_string();
        assert!(err.contains("only 2 survive"), "{err}");
        assert!(ReedSolomon::new(0, 2).is_err());
        assert!(ReedSolomon::new(2, 0).is_err());
        assert!(ReedSolomon::new(200, 57).is_err());
    }

    #[test]
    fn params_from_toml_and_shipped_config_match_defaults() {
        let p = ErasureParams::from_toml(
            "[erasure]\nk = 6\nm = 3\nstrip_bytes = \"2M\"\nencode_bw = 1.5e9\npolicy = \"buddy_ring\"\n",
        )
        .unwrap();
        assert_eq!((p.k, p.m), (6, 3));
        assert_eq!(p.strip_bytes, 2 * MIB);
        assert_eq!(p.encode_bw, 1.5e9);
        assert_eq!(p.policy, PlacementPolicy::BuddyRing);
        assert!(ErasureParams::from_toml("[erasure]\npolicy = \"raid0\"\n").is_err());
        let d = ErasureParams::from_toml("").unwrap();
        assert_eq!(d, ErasureParams::default().normalized());
        // The shipped site config states the defaults explicitly.
        let text = std::fs::read_to_string(
            std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs/polaris.toml"),
        )
        .unwrap();
        assert_eq!(
            ErasureParams::from_toml(&text).unwrap(),
            ErasureParams::default().normalized()
        );
    }

    #[test]
    fn planner_widths_are_aligned_and_cover() {
        let p = StripePlanner::new(4, DIRECT_IO_ALIGN);
        assert_eq!(p.strip_width(0), DIRECT_IO_ALIGN);
        assert_eq!(p.strip_width(16 * DIRECT_IO_ALIGN), 4 * DIRECT_IO_ALIGN);
        assert_eq!(p.strip_width(16 * DIRECT_IO_ALIGN + 1), 5 * DIRECT_IO_ALIGN);
        let payload: Vec<u8> = (0..10_000u32).map(|i| i as u8).collect();
        let strips = p.split(&payload);
        assert_eq!(strips.len(), 4);
        let w = p.strip_width(payload.len() as u64) as usize;
        assert!(strips.iter().all(|s| s.len() == w));
        let mut glued: Vec<u8> = strips.concat();
        glued.truncate(payload.len());
        assert_eq!(glued, payload);
    }

    #[test]
    fn placement_refuses_small_topologies() {
        // RS(4, 2) needs 6 foreign failure domains; 5 nodes of 1
        // domain each cannot host it — refuse, never degrade.
        let topo = Topology::polaris(20); // 5 single-node domains
        let err = ErasureTier::new(
            tmp("refuse"),
            topo,
            0,
            ErasureParams::default(),
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("failure domains"), "{err}");
    }

    #[test]
    fn encode_restore_roundtrip_and_degraded_decode() {
        let base = tmp("roundtrip");
        let src = base.join("src");
        fs::create_dir_all(&src).unwrap();
        let manifest = source_step(&src, 42, 100_000);
        let topo = Topology::polaris(28); // 7 single-node domains
        let et = ErasureTier::new(base.join("ec"), topo, 0, ErasureParams::default()).unwrap();
        let rep = et.encode_and_distribute(42, &src, &manifest, &[]).unwrap();
        assert_eq!(rep.acked.len(), 6);
        assert_eq!(rep.strip_width % DIRECT_IO_ALIGN, 0);
        assert_eq!(rep.parity_bytes, 2 * rep.strip_width);
        assert!(et.recoverable_at(42));
        assert_eq!(et.latest_recoverable_step(), Some(42));
        // Events: every strip synced strictly before its commit.
        let ev = et.events();
        for idx in 0..6 {
            let synced = ev
                .iter()
                .position(|e| matches!(e, ErasureEvent::StripSynced { index, .. } if *index == idx))
                .unwrap();
            let committed = ev
                .iter()
                .position(
                    |e| matches!(e, ErasureEvent::StripCommitted { index, .. } if *index == idx),
                )
                .unwrap();
            assert!(synced < committed);
        }
        // Intact restore: no decode needed.
        let (restored, survivors, degraded) = et.restore(42).unwrap();
        assert_eq!(survivors, 6);
        assert!(!degraded);
        let original = CheckpointStore::new(&src).load().unwrap();
        assert_bit_identical(&restored, &original);
        assert_eq!(et.degraded_restore_count(), 0);
        // Kill two holders — one data strip, one parity strip — and
        // restore again, now through the decoder.
        let h = et.holders().to_vec();
        et.fail_node(h[1]).unwrap();
        et.fail_node(h[4]).unwrap();
        assert_eq!(et.strip_count(42), 4);
        assert!(et.recoverable_at(42));
        let (restored, survivors, degraded) = et.restore(42).unwrap();
        assert_eq!(survivors, 4);
        assert!(degraded);
        assert_bit_identical(&restored, &original);
        assert_eq!(et.degraded_restore_count(), 1);
        // A third loss drops below k: loud failure naming the deficit.
        et.fail_node(h[2]).unwrap();
        assert!(!et.recoverable_at(42));
        assert_eq!(et.latest_recoverable_step(), None);
        let err = et.restore(42).unwrap_err().to_string();
        assert!(err.contains("only 3 survive"), "{err}");
    }

    #[test]
    fn recovery_scan_rebuilds_accounting_and_skips_uncommitted() {
        let base = tmp("recovery");
        let src = base.join("src");
        fs::create_dir_all(&src).unwrap();
        let manifest = source_step(&src, 9, 50_000);
        let topo = Topology::polaris(28);
        let root = base.join("ec");
        let et = ErasureTier::new(root.clone(), topo.clone(), 0, ErasureParams::default()).unwrap();
        et.encode_and_distribute(9, &src, &manifest, &[]).unwrap();
        let holders = et.holders().to_vec();
        // Crash mid-commit at one holder: simulate by deleting its
        // manifest (data + header persist, commit never landed).
        let broken = et.store_dir(0, holders[3], 9);
        fs::remove_file(broken.join(super::super::manifest::COMMIT_FILE)).unwrap();
        drop(et);
        let et2 = ErasureTier::new(root, topo, 0, ErasureParams::default()).unwrap();
        // The uncommitted strip is invisible; the other five recover.
        assert_eq!(et2.strip_count(9), 5);
        assert_eq!(et2.used_bytes(holders[3]), 0);
        assert!(et2.used_bytes(holders[0]) > 0);
        let (restored, survivors, _) = et2.restore(9).unwrap();
        assert_eq!(survivors, 5);
        assert_bit_identical(&restored, &CheckpointStore::new(&src).load().unwrap());
    }

    #[test]
    fn eviction_never_drops_below_k_without_durability() {
        let base = tmp("evict");
        let topo = Topology::polaris(28);
        let src = base.join("src");
        fs::create_dir_all(&src).unwrap();
        let m1 = source_step(&src, 1, 250_000);
        // Budget fits one strip + reservation slack but not two: the
        // exact width comes from the committed payload, the slack
        // margins mirror `reserve_room`'s `incoming/8 + 64 KiB`.
        let width = StripePlanner::new(4, DIRECT_IO_ALIGN).strip_width(m1.payload_bytes());
        let et = ErasureTier::new(
            base.join("ec"),
            topo,
            0,
            ErasureParams {
                strip_bytes: DIRECT_IO_ALIGN,
                ..ErasureParams::default()
            },
        )
        .unwrap()
        .with_capacity_per_node(width + width / 2 + (1 << 17));
        et.encode_and_distribute(1, &src, &m1, &[]).unwrap();
        assert!(et.recoverable_at(1));
        // Step 2 arrives; step 1 is durable nowhere — once its stripe
        // is ground down to k strips the remaining holders must
        // refuse, so the encode fails rather than dropping step 1
        // below k reachable strips.
        let src2 = base.join("src2");
        fs::create_dir_all(&src2).unwrap();
        let m2 = source_step(&src2, 2, 250_000);
        let err = et
            .encode_and_distribute(2, &src2, &m2, &[])
            .unwrap_err()
            .to_string();
        assert!(err.contains("will not fit budget"), "{err}");
        assert!(et.recoverable_at(1), "step 1 must survive the refusal");
        // The m strips above k were fair game (evicting a spare never
        // costs restorability); the last k are not — the stripe grinds
        // down to exactly k and the encode refuses there.
        assert_eq!(et.strip_count(1), 4);
        assert!(!et.recoverable_at(2));
        // Declare step 1 durable elsewhere: now eviction may proceed
        // and step 2 encodes.
        et.encode_and_distribute(2, &src2, &m2, &[1]).unwrap();
        assert!(et.recoverable_at(2));
        assert!(et.eviction_count() > 0);
        let ev = et.events();
        assert!(ev
            .iter()
            .any(|e| matches!(e, ErasureEvent::StripEvicted { step: 1, .. })));
        let (restored, _, _) = et.restore(2).unwrap();
        assert_bit_identical(&restored, &CheckpointStore::new(&src2).load().unwrap());
    }

    #[test]
    fn drain_plan_models_encode_cost_and_stripe_egress() {
        use crate::plan::PlanOp;
        let mut plan = RankPlan::new(0, 0);
        plan.add_file(FileSpec {
            path: "bb/step1/shard0.bin".to_string(),
            direct: true,
            size_hint: 64 * MIB,
            creates: true,
        });
        plan.push(PlanOp::Create { file: 0 });
        plan.push(PlanOp::Write {
            file: 0,
            offset: 0,
            src: BufSlice::new(0, 64 * MIB),
        });
        let params = ErasureParams::default();
        let holders = [1, 2, 3, 4, 5, 6];
        let dp = erasure_drain_plan(&plan, &holders, &params);
        // k+m strip files, each width-sized, addressed to the peers.
        let strips: Vec<&FileSpec> = dp.files.iter().filter(|f| f.creates).collect();
        assert_eq!(strips.len(), 6);
        let width = StripePlanner::new(4, params.strip_bytes).strip_width(64 * MIB);
        for (j, s) in strips.iter().enumerate() {
            assert!(s.path.starts_with(&format!("peer/n{}/", holders[j])), "{}", s.path);
            assert_eq!(s.size_hint, width);
        }
        // Egress = (k+m) * width = 1.5x payload for RS(4,2) —
        // fan-out-2 replication ships 2.0x.
        assert_eq!(dp.write_bytes(), 6 * width);
        assert!(dp.write_bytes() < 2 * plan.write_bytes());
        // The encode CPU cost is charged once, between read-back and
        // strip push.
        let cpu: Vec<u64> = dp
            .ops
            .iter()
            .filter_map(|op| match op {
                PlanOp::CpuWork { us } => Some(*us),
                _ => None,
            })
            .collect();
        let expect = ((64.0 * MIB as f64) / params.encode_bw * 1e6).ceil() as u64;
        assert_eq!(cpu, vec![expect]);
        // Read-back covers the full payload.
        assert_eq!(dp.read_bytes(), plan.write_bytes());
    }

    #[test]
    fn stripe_header_roundtrips() {
        let hdr = StripeHeader {
            owner: 3,
            step: 77,
            k: 4,
            m: 2,
            index: 5,
            width: 8192,
            payload_bytes: 30_000,
            files: vec![ManifestFile {
                path: "a/b.bin".to_string(),
                len: 30_000,
                crc: 0xdead_beef,
            }],
        };
        let dir = tmp("header");
        hdr.save(&dir).unwrap();
        let back = StripeHeader::load(&dir).unwrap();
        assert_eq!(hdr, back);
        let mut other = back.clone();
        other.index = 2;
        assert!(hdr.compatible(&other));
        other.width = 4096;
        assert!(!hdr.compatible(&other));
    }
}
