//! A deterministic pipeline model of the cascade, plus the plan
//! transform that turns a tier-targeted checkpoint plan into its
//! burst-buffer→PFS drain plan.
//!
//! The discrete-event simulator measures three primitives: the blocking
//! local write (`t_local`), the direct-to-PFS write (`t_pfs`), and the
//! bb→PFS drain (`t_drain`). [`CascadeModel`] composes them over a
//! checkpoint-interval sweep: write-back blocks the trainer only for
//! `t_local` per checkpoint — until the drain pump falls `drain_depth`
//! checkpoints behind, at which point the writer stalls (backpressure).
//! That is exactly the recurrence the fig19 bench sweeps.

use std::collections::VecDeque;

use crate::plan::{FileSpec, PlanOp, RankPlan};

use super::LOCAL_TIER_PREFIX;

/// Measured primitives + policy, composed analytically.
#[derive(Debug, Clone, Copy)]
pub struct CascadeModel {
    /// Blocking seconds per checkpoint when writing to the local tier.
    pub t_local: f64,
    /// Seconds to write the same checkpoint directly to the PFS.
    pub t_pfs: f64,
    /// Seconds to drain one checkpoint bb→PFS (background).
    pub t_drain: f64,
    /// Compute seconds between consecutive checkpoints.
    pub interval: f64,
    /// Max checkpoints queued or in flight upward before the writer
    /// stalls.
    pub drain_depth: usize,
}

impl CascadeModel {
    /// Makespan of `n` checkpoints, direct-to-PFS (no cascade): every
    /// checkpoint blocks for the full PFS write.
    pub fn direct_makespan(&self, n: u64) -> f64 {
        n as f64 * (self.interval + self.t_pfs)
    }

    /// Makespan of `n` checkpoints under write-back until the *trainer*
    /// is done (drains may still be in flight; durability lag is
    /// [`Self::writeback_drain_lag`]).
    pub fn writeback_makespan(&self, n: u64) -> f64 {
        self.simulate(n).0
    }

    /// Seconds after the trainer finishes until the last checkpoint is
    /// durable on the PFS.
    pub fn writeback_drain_lag(&self, n: u64) -> f64 {
        let (t, last_drain) = self.simulate(n);
        (last_drain - t).max(0.0)
    }

    /// (trainer finish time, last drain completion time).
    fn simulate(&self, n: u64) -> (f64, f64) {
        let depth = self.drain_depth.max(1);
        let mut t = 0.0f64; // trainer clock
        let mut drain_free = 0.0f64; // drain pump availability
        let mut pending: VecDeque<f64> = VecDeque::new(); // drain completions
        let mut last_drain = 0.0f64;
        for _ in 0..n {
            t += self.interval;
            // Retire drains that completed while computing.
            while pending.front().is_some_and(|&d| d <= t) {
                pending.pop_front();
            }
            // Backpressure: wait for a drain credit.
            while pending.len() >= depth {
                let head = *pending.front().expect("non-empty");
                t = t.max(head);
                pending.pop_front();
            }
            t += self.t_local;
            let done = drain_free.max(t) + self.t_drain;
            drain_free = done;
            last_drain = done;
            pending.push_back(done);
        }
        (t, last_drain)
    }
}

/// Transform a burst-buffer-targeted checkpoint plan (every file under
/// [`LOCAL_TIER_PREFIX`]) into its drain plan: read each written extent
/// back from the local tier and write it to the same path on the PFS.
/// The result runs on both executors, modeling the background pump as a
/// plan of its own.
pub fn writeback_drain_plan(plan: &RankPlan) -> RankPlan {
    drain_plan_with(plan, |stripped| stripped.to_string())
}

/// The shared drain transform: read each written extent back from the
/// local tier and write it to `dst_path(stripped)` — the PFS for the
/// write-back pump, a peer store for the replica pump
/// ([`crate::tier::replica::replica_drain_plan`]).
pub(crate) fn drain_plan_with(
    plan: &RankPlan,
    dst_path: impl Fn(&str) -> String,
) -> RankPlan {
    let mut out = RankPlan::new(plan.rank, plan.node);
    // For original file i: drain file ids 2i (bb source) / 2i+1 (dst).
    for spec in &plan.files {
        let stripped = spec
            .path
            .strip_prefix(LOCAL_TIER_PREFIX)
            .unwrap_or(&spec.path);
        out.add_file(FileSpec {
            path: spec.path.clone(),
            direct: spec.direct,
            size_hint: 0,
            creates: false,
        });
        out.add_file(FileSpec {
            path: dst_path(stripped),
            direct: spec.direct,
            size_hint: spec.size_hint,
            creates: true,
        });
    }
    for f in 0..plan.files.len() {
        out.push(PlanOp::Open { file: 2 * f });
        out.push(PlanOp::Create { file: 2 * f + 1 });
    }
    let writes: Vec<(usize, u64, crate::plan::BufSlice)> = plan
        .ops
        .iter()
        .filter_map(|op| match op {
            PlanOp::Write { file, offset, src } => Some((*file, *offset, *src)),
            _ => None,
        })
        .collect();
    for (file, offset, src) in &writes {
        out.push(PlanOp::Read {
            file: 2 * file,
            offset: *offset,
            dst: *src,
        });
    }
    out.push(PlanOp::Drain);
    for (file, offset, src) in &writes {
        out.push(PlanOp::Write {
            file: 2 * file + 1,
            offset: *offset,
            src: *src,
        });
    }
    out.push(PlanOp::Drain);
    for f in 0..plan.files.len() {
        out.push(PlanOp::Fsync { file: 2 * f + 1 });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::BufSlice;

    fn model(interval: f64, depth: usize) -> CascadeModel {
        CascadeModel {
            t_local: 0.5,
            t_pfs: 2.0,
            t_drain: 3.0,
            interval,
            drain_depth: depth,
        }
    }

    #[test]
    fn writeback_beats_direct_at_small_intervals() {
        let m = model(1.0, 4);
        let wb = m.writeback_makespan(8);
        let direct = m.direct_makespan(8);
        assert!(wb < direct, "writeback {wb} vs direct {direct}");
    }

    #[test]
    fn deep_drain_queue_not_slower() {
        let shallow = model(0.1, 1);
        let deep = model(0.1, 8);
        assert!(deep.writeback_makespan(16) <= shallow.writeback_makespan(16) + 1e-9);
    }

    #[test]
    fn long_intervals_hide_the_drain_entirely() {
        // interval >> t_drain: pump never falls behind, trainer pays
        // exactly n * (interval + t_local).
        let m = model(10.0, 2);
        let n = 6;
        let expect = n as f64 * (10.0 + 0.5);
        assert!((m.writeback_makespan(n) - expect).abs() < 1e-9);
        assert!(m.writeback_drain_lag(n) > 0.0);
    }

    #[test]
    fn backpressure_engages_when_drain_is_the_bottleneck() {
        // interval + t_local < t_drain: steady state is drain-limited;
        // makespan approaches n * t_drain regardless of depth.
        let m = model(0.1, 2);
        let n = 32;
        let ms = m.writeback_makespan(n);
        assert!(ms > (n as f64 - m.drain_depth as f64 - 1.0) * m.t_drain);
        // …but still beats synchronous direct writes of a slower tier
        // only when t_drain < interval + t_pfs; here it is worse than
        // t_pfs, so direct wins, which the model must reflect honestly.
        assert!(ms > m.direct_makespan(n) * 0.9);
    }

    #[test]
    fn drain_plan_mirrors_written_extents() {
        let mut p = RankPlan::new(0, 0);
        let f = p.add_file(FileSpec {
            path: format!("{LOCAL_TIER_PREFIX}r0.bin"),
            direct: true,
            size_hint: 1 << 20,
            creates: true,
        });
        p.push(PlanOp::Create { file: f });
        p.push(PlanOp::Write {
            file: f,
            offset: 0,
            src: BufSlice::new(0, 1 << 20),
        });
        p.push(PlanOp::Drain);
        p.push(PlanOp::Fsync { file: f });

        let d = writeback_drain_plan(&p);
        d.validate().unwrap();
        assert_eq!(d.files.len(), 2);
        assert!(d.files[0].path.starts_with(LOCAL_TIER_PREFIX));
        assert_eq!(d.files[1].path, "r0.bin");
        assert_eq!(d.read_bytes(), 1 << 20);
        assert_eq!(d.write_bytes(), 1 << 20);
        assert_eq!(d.staging_bytes(), p.staging_bytes());
    }
}
