//! The cascade's tier 0: GPU-HBM-resident checkpoint snapshots.
//!
//! The paper's traversal starts *on the device*: checkpoint state lives
//! in GPU memory and must cross PCIe (D2H) before any storage tier sees
//! it. DataStates-LLM's lazy multi-tier flush keeps the newest snapshots
//! device-resident so a rollback of a recent step never touches storage
//! at all; this module models that pattern on top of
//! [`crate::coordinator::gpu::DeviceTier`] (per the substitution rule we
//! have no A100s — the device tier is a host-memory region with
//! PCIe-rate-modeled transfers and an HBM capacity model):
//!
//! * **Pinning policy** — the newest `pin_depth` snapshots stay
//!   HBM-resident. Admission of a newer snapshot evicts oldest-first;
//!   whenever `pin_depth` snapshots fit the capacity, a snapshot within
//!   the pin window is never evicted (the property
//!   `tests/tier_cascade.rs` pins down).
//! * **Capacity model** — [`DeviceTier`] accounting against the
//!   A100-40GB budget ([`A100_40GB_HBM_BYTES`]; binary GiB, see the
//!   constant's docs for the GB-vs-GiB convention).
//! * **D2H drain model** — draining a snapshot to the host pool is
//!   charged at the PCIe rate (`payload / d2h_bw`); restores served
//!   from HBM charge the H2D rate. [`crate::tier::TierCascade`] surfaces
//!   both in its save reports.

use std::collections::BTreeMap;

use crate::ckpt::lean::Lean;
use crate::ckpt::store::RankData;
use crate::coordinator::gpu::{DeviceTier, A100_40GB_HBM_BYTES};
use crate::error::{Error, Result};

/// Default PCIe-4 x16 effective rate used when the caller does not
/// override it (matches `SimParams::polaris().d2h_bw`).
pub const DEFAULT_PCIE_BW: f64 = 22.0e9;

/// Observable device-stage transitions, in occurrence order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeviceEvent {
    /// `step`'s snapshot became HBM-resident (`bytes` of payload).
    Snapshotted { step: u64, bytes: u64 },
    /// `step`'s snapshot was evicted from HBM by the pinning policy
    /// (capacity displacement or pin-window trim). Replacing a step's
    /// own old incarnation on re-save is *not* an eviction and is not
    /// logged — the invariant "every eviction hits the then-oldest
    /// resident step" holds over this log.
    Evicted { step: u64 },
}

/// Outcome of one device-stage snapshot admission.
#[derive(Debug, Clone)]
pub struct DeviceSnapshotReport {
    pub step: u64,
    pub payload_bytes: u64,
    /// Steps evicted to admit this snapshot (capacity or pin-depth).
    pub evicted: Vec<u64>,
    /// Modeled seconds to drain this snapshot over PCIe (D2H).
    pub d2h_s: f64,
}

/// Per-(step, rank) tensor layout so snapshots reassemble exactly.
struct RankLayout {
    rank: usize,
    tensors: Vec<String>,
    lean: Lean,
}

/// The device tier of the checkpoint cascade: a [`DeviceTier`] capacity
/// model plus a newest-`k` pinning policy and PCIe drain modeling.
pub struct DeviceStage {
    hbm: DeviceTier,
    pin_depth: usize,
    d2h_bw: f64,
    h2d_bw: f64,
    /// step → payload bytes of the resident snapshot.
    resident: BTreeMap<u64, u64>,
    /// step → tensor layout for reassembly.
    layouts: BTreeMap<u64, Vec<RankLayout>>,
    events: Vec<DeviceEvent>,
}

fn buf_name(step: u64, rank: usize, tensor: &str) -> String {
    format!("step_{step:08}/r{rank}/{tensor}")
}

impl DeviceStage {
    /// A stage with `capacity` HBM bytes keeping the newest `pin_depth`
    /// snapshots resident.
    pub fn new(capacity: u64, pin_depth: usize) -> Self {
        Self {
            hbm: DeviceTier::new(capacity),
            pin_depth: pin_depth.max(1),
            d2h_bw: DEFAULT_PCIE_BW,
            h2d_bw: DEFAULT_PCIE_BW,
            resident: BTreeMap::new(),
            layouts: BTreeMap::new(),
            events: Vec::new(),
        }
    }

    /// The A100-40GB capacity model ([`A100_40GB_HBM_BYTES`], binary
    /// GiB).
    pub fn a100_40gb(pin_depth: usize) -> Self {
        Self::new(A100_40GB_HBM_BYTES, pin_depth)
    }

    /// Override the modeled PCIe rates (bytes/s, D2H and H2D).
    pub fn with_pcie_bw(mut self, d2h_bw: f64, h2d_bw: f64) -> Self {
        assert!(d2h_bw > 0.0 && h2d_bw > 0.0);
        self.d2h_bw = d2h_bw;
        self.h2d_bw = h2d_bw;
        self
    }

    pub fn pin_depth(&self) -> usize {
        self.pin_depth
    }

    pub fn capacity(&self) -> u64 {
        self.hbm.capacity()
    }

    pub fn resident_bytes(&self) -> u64 {
        self.hbm.used()
    }

    /// Is `step`'s snapshot HBM-resident?
    pub fn contains(&self, step: u64) -> bool {
        self.resident.contains_key(&step)
    }

    /// Resident (pinned) steps, ascending.
    pub fn resident_steps(&self) -> Vec<u64> {
        self.resident.keys().copied().collect()
    }

    /// The event log so far.
    pub fn events(&self) -> Vec<DeviceEvent> {
        self.events.clone()
    }

    /// Policy evictions so far (the [`DeviceEvent::Evicted`] entries) —
    /// re-save replacements are not counted.
    pub fn eviction_count(&self) -> u64 {
        self.events
            .iter()
            .filter(|e| matches!(e, DeviceEvent::Evicted { .. }))
            .count() as u64
    }

    /// Modeled D2H drain seconds for `payload` bytes.
    pub fn d2h_seconds(&self, payload: u64) -> f64 {
        payload as f64 / self.d2h_bw
    }

    /// Modeled H2D placement seconds for `payload` bytes.
    pub fn h2d_seconds(&self, payload: u64) -> f64 {
        payload as f64 / self.h2d_bw
    }

    fn payload_of(data: &[RankData]) -> u64 {
        data.iter()
            .map(|d| d.tensors.iter().map(|(_, b)| b.len() as u64).sum::<u64>())
            .sum()
    }

    /// Drop `step`'s buffers and accounting. `log_evict` distinguishes
    /// a policy eviction (logged) from a re-save replacement (not an
    /// eviction; see [`DeviceEvent::Evicted`]).
    fn drop_step(&mut self, step: u64, log_evict: bool) {
        if let Some(layouts) = self.layouts.remove(&step) {
            for l in &layouts {
                for t in &l.tensors {
                    self.hbm.evict(&buf_name(step, l.rank, t));
                }
            }
        }
        if self.resident.remove(&step).is_some() && log_evict {
            self.events.push(DeviceEvent::Evicted { step });
        }
    }

    /// Admit `step`'s snapshot into HBM (the H2D side happens during
    /// training; here the state is already "on device" — we place and
    /// account it). Eviction is strictly oldest-first: first anything
    /// beyond the pin window, then — only to admit a strictly newer
    /// snapshot — pinned steps, newest-first wins. Whenever `pin_depth`
    /// snapshots fit the capacity, no step within the window is ever
    /// evicted. A snapshot larger than the whole device errs.
    pub fn snapshot(&mut self, step: u64, data: &[RankData]) -> Result<DeviceSnapshotReport> {
        let payload = Self::payload_of(data);
        if payload > self.hbm.capacity() {
            return Err(Error::msg(format!(
                "device OOM: snapshot of step {step} is {payload} bytes > HBM capacity {}",
                self.hbm.capacity()
            )));
        }
        // Plan the evictions BEFORE mutating anything, so a failed
        // admission leaves the stage exactly as it was (no dropped
        // re-save incarnation, no hole in the pin window). Victims are
        // strictly oldest-first; a *newer* snapshot always wins over a
        // pinned older one (the pin window slides forward when `step`
        // lands), but an older re-save never displaces newer snapshots.
        let old_bytes = self.resident.get(&step).copied().unwrap_or(0);
        let fits = |freed: u64, this: &Self| {
            this.hbm.used().saturating_sub(old_bytes + freed) + payload <= this.hbm.capacity()
        };
        let mut victims: Vec<u64> = Vec::new();
        let mut freed = 0u64;
        for (&s, &b) in &self.resident {
            if fits(freed, self) {
                break;
            }
            if s == step {
                continue;
            }
            if s > step {
                return Err(Error::msg(format!(
                    "device OOM: step {step} will not fit without evicting newer snapshots"
                )));
            }
            victims.push(s);
            freed += b;
        }
        if !fits(freed, self) {
            return Err(Error::msg(format!(
                "device OOM: step {step} will not fit without evicting newer snapshots"
            )));
        }
        // Commit the plan: replace the old incarnation, evict victims.
        if old_bytes > 0 {
            self.drop_step(step, false);
        }
        let mut evicted = Vec::new();
        for v in victims {
            self.drop_step(v, true);
            evicted.push(v);
        }
        // Place the buffers.
        let mut layouts = Vec::with_capacity(data.len());
        for d in data {
            let mut names = Vec::with_capacity(d.tensors.len());
            for (name, bytes) in &d.tensors {
                self.hbm.put(&buf_name(step, d.rank, name), bytes.clone())?;
                names.push(name.clone());
            }
            layouts.push(RankLayout {
                rank: d.rank,
                tensors: names,
                lean: d.lean.clone(),
            });
        }
        self.layouts.insert(step, layouts);
        self.resident.insert(step, payload);
        self.events.push(DeviceEvent::Snapshotted {
            step,
            bytes: payload,
        });
        // Pin-depth trim: only the newest `pin_depth` stay resident.
        while self.resident.len() > self.pin_depth {
            let oldest = *self.resident.keys().next().expect("non-empty");
            self.drop_step(oldest, true);
            evicted.push(oldest);
        }
        Ok(DeviceSnapshotReport {
            step,
            payload_bytes: payload,
            evicted,
            d2h_s: self.d2h_seconds(payload),
        })
    }

    /// Reassemble `step` from HBM (the restore fast path; also the D2H
    /// read side of the cascade's drain). Returns the data plus the
    /// modeled PCIe seconds for moving it.
    pub fn fetch(&self, step: u64) -> Option<(Vec<RankData>, f64)> {
        let payload = *self.resident.get(&step)?;
        let layouts = self.layouts.get(&step)?;
        let mut out = Vec::with_capacity(layouts.len());
        for l in layouts {
            let mut tensors = Vec::with_capacity(l.tensors.len());
            for t in &l.tensors {
                let bytes = self.hbm.get(&buf_name(step, l.rank, t))?;
                tensors.push((t.clone(), bytes.to_vec()));
            }
            out.push(RankData {
                rank: l.rank,
                tensors,
                lean: l.lean.clone(),
            });
        }
        Some((out, self.h2d_seconds(payload)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ckpt::lean;
    use crate::util::prng::Xoshiro256;

    fn data(rank: usize, bytes: usize, seed: u64) -> RankData {
        let mut rng = Xoshiro256::seeded(seed);
        let mut b = vec![0u8; bytes];
        rng.fill_bytes(&mut b);
        RankData {
            rank,
            tensors: vec![(format!("w{rank}"), b)],
            lean: lean::training_state(seed, 1e-3, "dev"),
        }
    }

    #[test]
    fn newest_k_stay_resident() {
        let mut s = DeviceStage::new(1 << 20, 2);
        for step in 1..=4u64 {
            s.snapshot(step, &[data(0, 10_000, step)]).unwrap();
        }
        assert_eq!(s.resident_steps(), vec![3, 4]);
        // Evictions were strictly oldest-first.
        let evictions: Vec<u64> = s
            .events()
            .iter()
            .filter_map(|e| match e {
                DeviceEvent::Evicted { step } => Some(*step),
                _ => None,
            })
            .collect();
        assert_eq!(evictions, vec![1, 2]);
        assert_eq!(s.eviction_count(), 2);
    }

    #[test]
    fn capacity_evicts_oldest_first_for_newer() {
        // Capacity for one snapshot only; pin depth 3 cannot be met.
        let mut s = DeviceStage::new(15_000, 3);
        s.snapshot(1, &[data(0, 10_000, 1)]).unwrap();
        let rep = s.snapshot(2, &[data(0, 10_000, 2)]).unwrap();
        assert_eq!(rep.evicted, vec![1]);
        assert_eq!(s.resident_steps(), vec![2]);
    }

    #[test]
    fn eviction_is_policy_driven_and_never_hits_the_pin_window() {
        // Eviction has no manual entry point: a snapshot leaves HBM
        // only when a newer admission displaces it (capacity) or pushes
        // it past the pin window (trim). At every instant the resident
        // set is exactly the newest min(saved, k) steps.
        let mut s = DeviceStage::new(1 << 20, 3);
        for step in 1..=6u64 {
            s.snapshot(step, &[data(0, 1_000, step)]).unwrap();
            let expect: Vec<u64> = (1..=step).rev().take(3).rev().collect();
            assert_eq!(s.resident_steps(), expect, "after step {step}");
        }
        // Replaying the event log: every eviction hit the then-oldest
        // resident step — oldest-first means a step within the newest-k
        // window is never the victim.
        let mut resident: Vec<u64> = Vec::new();
        for e in s.events() {
            match e {
                DeviceEvent::Snapshotted { step, .. } => resident.push(step),
                DeviceEvent::Evicted { step } => {
                    let oldest = *resident.iter().min().unwrap();
                    assert_eq!(step, oldest, "eviction must be oldest-first");
                    resident.retain(|&s| s != step);
                }
            }
        }
    }

    #[test]
    fn fetch_is_bit_exact_and_models_pcie() {
        let mut s = DeviceStage::new(1 << 20, 2).with_pcie_bw(1e9, 2e9);
        let input = vec![data(0, 50_000, 7), data(1, 50_000, 8)];
        let rep = s.snapshot(7, &input).unwrap();
        assert_eq!(rep.payload_bytes, 100_000);
        assert!((rep.d2h_s - 100_000.0 / 1e9).abs() < 1e-12);
        let (back, h2d_s) = s.fetch(7).unwrap();
        assert!((h2d_s - 100_000.0 / 2e9).abs() < 1e-12);
        for (a, b) in input.iter().zip(&back) {
            assert_eq!(a.rank, b.rank);
            assert_eq!(a.tensors, b.tensors);
        }
        assert!(s.fetch(99).is_none());
    }

    #[test]
    fn oversized_snapshot_rejected() {
        let mut s = DeviceStage::new(1_000, 2);
        assert!(s.snapshot(1, &[data(0, 2_000, 1)]).is_err());
        assert!(s.resident_steps().is_empty());
    }

    #[test]
    fn failed_resave_admission_leaves_stage_untouched() {
        // Regression: a re-save that cannot be admitted (it would need
        // to displace newer snapshots) must not drop the step's old
        // incarnation or evict anything — admission is planned before
        // any mutation.
        let mut s = DeviceStage::new(4_800, 3);
        for step in 1..=3u64 {
            s.snapshot(step, &[data(0, 1_600, step)]).unwrap();
        }
        assert_eq!(s.resident_steps(), vec![1, 2, 3]);
        let err = s.snapshot(1, &[data(0, 4_096, 11)]).unwrap_err();
        assert!(err.to_string().contains("newer snapshots"), "{err}");
        // Nothing changed: all three snapshots still resident, and the
        // old incarnation of step 1 still fetches bit-exactly.
        assert_eq!(s.resident_steps(), vec![1, 2, 3]);
        assert_eq!(s.resident_bytes(), 4_800);
        let (back, _) = s.fetch(1).unwrap();
        assert_eq!(back[0].tensors, data(0, 1_600, 1).tensors);
    }

    #[test]
    fn resave_replaces_in_place() {
        let mut s = DeviceStage::new(1 << 20, 2);
        s.snapshot(5, &[data(0, 10_000, 5)]).unwrap();
        s.snapshot(5, &[data(0, 20_000, 55)]).unwrap();
        assert_eq!(s.resident_steps(), vec![5]);
        assert_eq!(s.resident_bytes(), 20_000);
        let (back, _) = s.fetch(5).unwrap();
        assert_eq!(back[0].tensors, data(0, 20_000, 55).tensors);
    }
}
