//! `tier` — the hierarchical checkpoint cascade.
//!
//! The paper frames checkpointing as traversal of a storage stack whose
//! tiers "differ by orders of magnitude in performance": GPU HBM → host
//! DRAM → node-local NVMe → the parallel file system. The engines under
//! study flatten that stack into a single hop (host → PFS); this module
//! restores the hierarchy — the TierCheck / DataStates-LLM production
//! pattern of a local **burst buffer** that absorbs checkpoints at NVMe
//! speed and drains them to the PFS asynchronously:
//!
//! * [`device::DeviceStage`] — the cascade's tier 0: GPU-HBM-resident
//!   snapshots with a newest-*k* pinning policy, the A100-40GB capacity
//!   model, and PCIe-rate-modeled D2H/H2D transfers.
//! * [`cascade::TierCascade`] — stages checkpoint objects through an
//!   ordered list of persistent tiers (pinned host pool → local-NVMe
//!   burst-buffer directory → PFS directory) with per-tier capacity
//!   accounting, eviction, and a [`TierPolicy`] governing when data
//!   moves upward.
//! * [`manifest::TierManifest`] — the crash-consistency unit: a
//!   checkpoint is durable *at a tier* only once its manifest commits
//!   there (written atomically via temp-file + rename, strictly after
//!   the data blocks are fsynced).
//! * [`writeback`] — the asynchronous drain pump: background workers
//!   copy committed checkpoints to the next tier through per-tier
//!   [`crate::iobackend::RankIo`] backends, bounded by a drain-depth
//!   semaphore built on [`crate::coordinator::backpressure`].
//! * [`prefetch`] — restore-side pipelining: while one checkpoint's
//!   shards load, the next one's files are pulled from the PFS into the
//!   burst buffer.
//! * [`replica`] — the inter-node peer replica tier between the burst
//!   buffer and the PFS: each rank group's burst-buffer shards copy
//!   asynchronously to buddy nodes chosen by a failure-domain-aware
//!   placement policy over [`crate::coordinator::Topology`], so a
//!   single-node loss restores at fabric speed instead of paying the
//!   PFS (TierCheck's replica layer; `benches/fig21_replica_tier.rs`).
//! * [`registry`] — the copies registry: one lock spanning cascade and
//!   replica eviction decisions, so a PFS eviction and a replica
//!   eviction can never concurrently drop what each believed was a
//!   redundant copy of the same step.
//! * [`model`] — a deterministic pipeline model of the cascade used to
//!   compose simulator measurements into interval sweeps
//!   (`benches/fig19_tiered_cascade.rs`).
//!
//! On the simulated substrate the cascade is expressed through file
//! paths: plans whose files start with [`LOCAL_TIER_PREFIX`] are routed
//! to the per-node local-SSD rate servers of [`crate::simpfs`] instead
//! of the NIC/OST path (engines expose a constructor knob to emit such
//! plans).

pub mod cascade;
pub mod device;
pub mod erasure;
pub mod manifest;
pub mod model;
pub mod prefetch;
pub mod registry;
pub mod replica;
pub mod writeback;

pub use cascade::{TierCascade, TierEvent, TierSaveReport, TierSpec};
pub use device::{DeviceEvent, DeviceSnapshotReport, DeviceStage};
pub use erasure::{
    erasure_drain_plan, ErasureEvent, ErasureParams, ErasureReport, ErasureTier, ReedSolomon,
    StripePlanner,
};
pub use manifest::TierManifest;
pub use model::CascadeModel;
pub use prefetch::RestorePrefetcher;
pub use registry::CopiesRegistry;
pub use replica::{PlacementPolicy, ReplicaEvent, ReplicaReport, ReplicaTier};

/// Identifies where in the cascade a checkpoint copy lives: the
/// (volatile) device tier 0, a buddy node's peer replica store, or a
/// persistent storage tier by index (0 = fastest, i.e. the burst
/// buffer; last = the PFS).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tier {
    /// GPU-HBM-resident snapshot ([`DeviceStage`]) — the cascade's
    /// tier 0, in front of every storage tier.
    Device,
    /// A buddy node's peer replica store ([`ReplicaTier`]); the value
    /// is the buddy node that served the copy. Sits between the burst
    /// buffer and the slower tiers in restore preference.
    Replica(usize),
    /// The erasure-coded stripe ([`ErasureTier`]): a *logical* copy
    /// reconstructible from any k surviving strips. No single node
    /// holds it, so there is no node payload — and a single strip
    /// holder must never be mistaken for this tier. Slower to serve
    /// than a whole replica (k fabric reads + a possible decode),
    /// faster than the PFS.
    Erasure,
    /// Persistent storage tier by cascade index.
    Storage(usize),
}

impl Tier {
    /// The storage-tier index, if this is a storage tier.
    pub fn storage_index(&self) -> Option<usize> {
        match self {
            Tier::Device | Tier::Replica(_) | Tier::Erasure => None,
            Tier::Storage(i) => Some(*i),
        }
    }
}

impl std::fmt::Display for Tier {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Tier::Device => write!(f, "device"),
            Tier::Replica(n) => write!(f, "replica{n}"),
            Tier::Erasure => write!(f, "erasure"),
            Tier::Storage(i) => write!(f, "storage{i}"),
        }
    }
}

/// Path prefix marking a plan file as living on the node-local
/// burst-buffer tier. The simulator routes such files to the local-SSD
/// rate servers; on real storage the prefix is a directory under the
/// run root, so the same plans work on both substrates.
pub const LOCAL_TIER_PREFIX: &str = "bb/";

/// Path prefix marking a plan file as living in a peer node's replica
/// store: `peer/n{dst}/…` addresses node `dst`. The simulator routes
/// such files over the per-node peer-fabric lane (`net_peer_*`
/// [`crate::simpfs::SimParams`]) with egress sharing the node's NIC
/// port; on real storage [`ReplicaTier`] maps the same logical layout
/// to per-node directories.
pub const PEER_TIER_PREFIX: &str = "peer/";

/// How checkpoints propagate through the cascade.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TierPolicy {
    /// Synchronous replication: a save returns only after every tier has
    /// committed (durable everywhere, slowest).
    WriteThrough,
    /// Commit locally, drain to the next tier on background workers.
    /// At most `drain_depth` checkpoints may be queued or in flight
    /// upward; beyond that the writer blocks (backpressure).
    WriteBack { drain_depth: usize },
    /// TierCheck-style mixed frequency: every checkpoint commits to the
    /// local tier; every `k`-th additionally drains (asynchronously) to
    /// the slower tiers.
    LocalOnlyEveryK { k: u64 },
}

impl TierPolicy {
    /// Does checkpoint `step` propagate beyond the first tier?
    pub fn propagates(&self, step: u64) -> bool {
        match self {
            TierPolicy::WriteThrough | TierPolicy::WriteBack { .. } => true,
            TierPolicy::LocalOnlyEveryK { k } => *k > 0 && step % *k == 0,
        }
    }

    /// Upward-drain concurrency bound (checkpoints queued or in flight).
    pub fn drain_depth(&self) -> usize {
        match self {
            TierPolicy::WriteBack { drain_depth } => (*drain_depth).max(1),
            _ => 1,
        }
    }
}

/// Join a tier prefix onto an engine-generated path.
pub fn tier_path(prefix: &str, path: &str) -> String {
    if prefix.is_empty() {
        path.to_string()
    } else if prefix.ends_with('/') {
        format!("{prefix}{path}")
    } else {
        format!("{prefix}/{path}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_propagation() {
        assert!(TierPolicy::WriteThrough.propagates(1));
        assert!(TierPolicy::WriteBack { drain_depth: 2 }.propagates(7));
        let k3 = TierPolicy::LocalOnlyEveryK { k: 3 };
        assert!(!k3.propagates(1));
        assert!(!k3.propagates(2));
        assert!(k3.propagates(3));
        assert!(k3.propagates(6));
        // k = 0 never propagates (and never divides by zero).
        assert!(!TierPolicy::LocalOnlyEveryK { k: 0 }.propagates(4));
    }

    #[test]
    fn drain_depth_floor() {
        assert_eq!(TierPolicy::WriteBack { drain_depth: 0 }.drain_depth(), 1);
        assert_eq!(TierPolicy::WriteBack { drain_depth: 4 }.drain_depth(), 4);
        assert_eq!(TierPolicy::WriteThrough.drain_depth(), 1);
    }

    #[test]
    fn tier_display_and_index() {
        assert_eq!(Tier::Device.to_string(), "device");
        assert_eq!(Tier::Replica(3).to_string(), "replica3");
        assert_eq!(Tier::Erasure.to_string(), "erasure");
        assert_eq!(Tier::Storage(1).to_string(), "storage1");
        assert_eq!(Tier::Device.storage_index(), None);
        assert_eq!(Tier::Replica(3).storage_index(), None);
        assert_eq!(Tier::Erasure.storage_index(), None);
        assert_eq!(Tier::Storage(2).storage_index(), Some(2));
    }

    #[test]
    fn tier_path_joins() {
        assert_eq!(tier_path("", "a/b.bin"), "a/b.bin");
        assert_eq!(tier_path("bb/", "a.bin"), "bb/a.bin");
        assert_eq!(tier_path("bb", "a.bin"), "bb/a.bin");
        assert!(tier_path(LOCAL_TIER_PREFIX, "x").starts_with(LOCAL_TIER_PREFIX));
    }
}
