//! `TierCascade` — staged checkpointing through an ordered tier list.
//!
//! The storage tiers run fastest-first: storage tier 0 is the
//! node-local NVMe burst buffer; the last tier is the slowest and most
//! durable (the PFS). An optional [`DeviceStage`] sits in front of
//! everything as the cascade's tier 0 proper — GPU-HBM-resident
//! snapshots with a newest-*k* pinning policy and a PCIe-rate-modeled
//! D2H drain feeding the pinned host staging pool, which is governed
//! by a byte-budget [`Backpressure`] gate. Each save:
//!
//! 1. admits the checkpoint's bytes against the host pool budget;
//! 2. makes room at tier 0 (evicting checkpoints that are durable
//!    further up, or obsolete local-only ones);
//! 3. writes + fsyncs the data through tier 0's I/O backend and then —
//!    and only then — commits the tier-0 manifest;
//! 4. propagates per [`TierPolicy`]: synchronously (write-through),
//!    via background drain workers bounded by a drain-depth semaphore
//!    (write-back), or only every k-th checkpoint (TierCheck-style).
//!
//! Restores walk the cascade fastest-first and fall past tiers whose
//! copy is missing or fails verification. [`TierCascade::prefetch`]
//! pulls a checkpoint from a slow tier into the burst buffer in the
//! background so the next restore hits tier 0.

use std::collections::{BTreeMap, BTreeSet};
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use crate::ckpt::delta::{DeltaJournal, DeltaParams, DeltaSaveReport, DeltaStore};
use crate::ckpt::store::{CheckpointStore, RankData};
use crate::coordinator::backpressure::Backpressure;
use crate::error::{Error, Result};
use crate::exec::real::BackendKind;
use crate::trace::{
    Counter, Span, TraceHandle, TraceSummary, SPAN_BB_WRITE, SPAN_D2H_DRAIN, SPAN_ERASURE_DECODE,
    SPAN_ERASURE_ENCODE, SPAN_EVICT, SPAN_PFS_FLUSH, SPAN_PREFETCH, SPAN_REPLICATE,
    SPAN_RESHARD_READ, SPAN_RESTORE, SPAN_SAVE,
};
use crate::util::bytes::GIB;
use crate::util::threadpool::ThreadPool;
use crate::util::timer::Stopwatch;

use super::device::DeviceStage;
use super::erasure::ErasureTier;
use super::manifest::TierManifest;
use super::registry::CopiesRegistry;
use super::replica::ReplicaTier;
use super::{writeback, Tier, TierPolicy};

/// One persistent tier of the cascade.
#[derive(Debug, Clone)]
pub struct TierSpec {
    pub name: String,
    pub root: PathBuf,
    /// Capacity in bytes (`u64::MAX` = unbounded). Enforced on the
    /// first tier (save-side admission and eviction, and prefetch
    /// skips when full); slower tiers are accounted but not gated.
    pub capacity: u64,
    /// I/O backend plans use against this tier's directory.
    pub backend: BackendKind,
}

impl TierSpec {
    pub fn new(name: impl Into<String>, root: impl Into<PathBuf>) -> Self {
        Self {
            name: name.into(),
            root: root.into(),
            capacity: u64::MAX,
            backend: BackendKind::uring(64, 16),
        }
    }

    pub fn with_capacity(mut self, bytes: u64) -> Self {
        self.capacity = bytes;
        self
    }

    pub fn with_backend(mut self, backend: BackendKind) -> Self {
        self.backend = backend;
        self
    }
}

/// Observable cascade transitions, in occurrence order. The invariant
/// the property tests pin down: a `ManifestCommitted { tier, step }` is
/// always preceded by its `DataSynced { tier, step }`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TierEvent {
    /// All data blocks of `step` are written + fsynced at `tier`.
    DataSynced { tier: usize, step: u64 },
    /// The commit manifest of `step` landed at `tier` (now durable).
    ManifestCommitted { tier: usize, step: u64 },
    /// `step`'s copy at `tier` was evicted.
    Evicted { tier: usize, step: u64 },
    /// `step` was prefetched back into `tier`.
    Prefetched { tier: usize, step: u64 },
}

/// Outcome of one cascade save.
#[derive(Debug, Clone)]
pub struct TierSaveReport {
    pub step: u64,
    pub payload_bytes: u64,
    /// Wall seconds the caller was blocked (local write, plus any
    /// synchronous replication or drain backpressure).
    pub blocking_s: f64,
    /// Of which: the tier-0 write + commit itself.
    pub local_s: f64,
    /// True if the save replicated through all tiers synchronously.
    pub drained_sync: bool,
    /// True if the snapshot is HBM-resident in the device stage (only
    /// when a [`DeviceStage`] is attached and admission succeeded).
    pub device_resident: bool,
    /// Modeled PCIe seconds to drain the snapshot device→host (0.0
    /// without a device stage). Virtual time — the substitution rule
    /// means no real GPU is on the path, so this is *not* part of
    /// `blocking_s`.
    pub d2h_s: f64,
    /// Delta-save accounting when the save went through
    /// [`TierCascade::save_delta`]: chunks skipped vs written and the
    /// parent step. `None` for full-store saves. Note that
    /// `payload_bytes` is then the *delta* payload — the only bytes
    /// drains, replication and swarm seeding ever ship for this step.
    pub delta: Option<DeltaSaveReport>,
}

struct CascadeState {
    /// Per tier: step → committed payload bytes.
    resident: Vec<BTreeMap<u64, u64>>,
    /// Steps with an in-flight or queued upward drain (eviction-safe).
    draining: BTreeSet<u64>,
    events: Vec<TierEvent>,
    errors: Vec<String>,
}

/// Live delta-chain bookkeeping behind [`TierCascade::save_delta`].
/// Guarded by its own mutex, never held across the cascade's other
/// locks — callers snapshot what they need and drop it.
struct DeltaState {
    params: DeltaParams,
    /// The newest committed step's journal — the next save's parent.
    parent: Option<DeltaJournal>,
    /// Steps of the live chain, newest first. An inherited chunk of the
    /// head may point into any of these, so eviction refuses to drop a
    /// member's sole surviving copy even when a newer step exists.
    chain: Vec<u64>,
    /// Delta saves since the last full snapshot (drives the
    /// `compact_every` keyframe schedule).
    saves_since_full: u64,
}

/// The hierarchical checkpoint cascade.
pub struct TierCascade {
    tiers: Vec<TierSpec>,
    policy: TierPolicy,
    queue_depth: u32,
    host_bp: Arc<Backpressure>,
    drain_credits: Arc<Backpressure>,
    pool: ThreadPool,
    inner: Arc<Mutex<CascadeState>>,
    /// Optional device tier 0 in front of the storage tiers.
    device: Option<Mutex<DeviceStage>>,
    /// Optional inter-node replica tier between the burst buffer and
    /// the slower tiers: saves enqueue asynchronous replication to
    /// buddy nodes; restores fall back bb → replica → PFS.
    replica: Option<Arc<ReplicaTier>>,
    /// Optional erasure-coded stripe tier ([`ErasureTier`]): saves
    /// enqueue an asynchronous RS(k,m) encode + strip distribution
    /// across failure domains; restores fall back bb → replica →
    /// stripe → PFS, reconstructing from any k surviving strips.
    erasure: Option<Arc<ErasureTier>>,
    /// The copies registry: one lock spanning this cascade's and the
    /// replica tier's eviction decisions (see [`CopiesRegistry`]).
    registry: Arc<CopiesRegistry>,
    /// Optional fleet-wide copies control plane: `(this node's id,
    /// the shared registry)`. When attached, every whole-step tier
    /// copy this cascade commits or evicts is mirrored there, and
    /// restores consult its fastest-surviving hint (a live buddy
    /// replica outranks the storage walk even on a node whose local
    /// state is gone).
    swarm: Option<(usize, Arc<crate::swarm::SwarmRegistry>)>,
    /// Optional delta-checkpointing mode (see [`Self::with_delta`]):
    /// saves through [`Self::save_delta`] persist only changed chunks
    /// against the previous step, so every downstream byte-mover —
    /// drains, replica fan-out, swarm seeding — ships delta bytes.
    delta: Option<Mutex<DeltaState>>,
    /// Lifecycle trace sink: save/drain/evict/restore/prefetch spans
    /// plus the tier-resident counters (see [`crate::trace`]).
    trace: TraceHandle,
}

pub(crate) fn step_dirname(step: u64) -> String {
    format!("step_{step:08}")
}

pub(crate) fn parse_step_dirname(name: &str) -> Option<u64> {
    name.strip_prefix("step_")?.parse().ok()
}

fn step_dir_of(tier: &TierSpec, step: u64) -> PathBuf {
    tier.root.join(step_dirname(step))
}

/// Best-effort burst-buffer room check for the prefetch workers: false
/// when the incoming payload (plus store padding slack) would push
/// tier 0 past its capacity.
fn burst_has_room(tiers: &[TierSpec], inner: &Arc<Mutex<CascadeState>>, payload: u64) -> bool {
    let cap = tiers[0].capacity;
    if cap == u64::MAX {
        return true;
    }
    let used: u64 = inner.lock().unwrap().resident[0].values().sum();
    used.saturating_add(payload + payload / 8) <= cap
}

/// Copy `manifest`'s files from `src_dir` into `dst`'s step directory
/// and commit there — data strictly before manifest, events and
/// accounting after — the one commit protocol shared by the drain
/// workers, the write-through path, and both prefetch sources (a
/// slower tier via [`promote`], a buddy replica store directly).
#[allow(clippy::too_many_arguments)]
fn land_at_tier(
    src_dir: &std::path::Path,
    src_backend: BackendKind,
    dst: &TierSpec,
    dst_tier_index: usize,
    step: u64,
    manifest: &TierManifest,
    queue_depth: u32,
    inner: &Arc<Mutex<CascadeState>>,
    registry: &Arc<CopiesRegistry>,
) -> Result<()> {
    let dst_dir = step_dir_of(dst, step);
    std::fs::create_dir_all(&dst_dir)?;
    let files: Vec<(String, u64)> = manifest
        .files
        .iter()
        .map(|f| (f.path.clone(), f.len))
        .collect();
    writeback::copy_files(
        &files,
        src_dir,
        &dst_dir,
        src_backend,
        dst.backend,
        queue_depth,
    )?;
    inner.lock().unwrap().events.push(TierEvent::DataSynced {
        tier: dst_tier_index,
        step,
    });
    manifest.commit(&dst_dir)?;
    {
        let mut st = inner.lock().unwrap();
        st.events.push(TierEvent::ManifestCommitted {
            tier: dst_tier_index,
            step,
        });
        st.resident[dst_tier_index].insert(step, manifest.payload_bytes());
    }
    // Registry after the component lock is released (lock ordering).
    registry.lock().record_storage(dst_tier_index, step);
    Ok(())
}

/// Copy `step` between two tier directories and commit at the
/// destination.
#[allow(clippy::too_many_arguments)]
fn promote(
    src: &TierSpec,
    dst: &TierSpec,
    dst_tier_index: usize,
    step: u64,
    manifest: &TierManifest,
    queue_depth: u32,
    inner: &Arc<Mutex<CascadeState>>,
    registry: &Arc<CopiesRegistry>,
) -> Result<()> {
    land_at_tier(
        &step_dir_of(src, step),
        src.backend,
        dst,
        dst_tier_index,
        step,
        manifest,
        queue_depth,
        inner,
        registry,
    )
}

/// Drain `step` from tier 0 through every remaining tier in order.
fn drain_chain(
    tiers: &[TierSpec],
    inner: &Arc<Mutex<CascadeState>>,
    registry: &Arc<CopiesRegistry>,
    queue_depth: u32,
    step: u64,
    manifest: &TierManifest,
) -> Result<()> {
    for i in 1..tiers.len() {
        promote(
            &tiers[i - 1],
            &tiers[i],
            i,
            step,
            manifest,
            queue_depth,
            inner,
            registry,
        )?;
    }
    Ok(())
}

impl TierCascade {
    /// Build a cascade over `tiers` (fastest first; at least one).
    /// Existing committed checkpoint directories under the tier roots
    /// are recovered into the resident sets — the crash-restart path.
    pub fn new(tiers: Vec<TierSpec>, policy: TierPolicy) -> Result<Self> {
        if tiers.is_empty() {
            return Err(Error::config("TierCascade needs at least one tier"));
        }
        let mut resident: Vec<BTreeMap<u64, u64>> = Vec::with_capacity(tiers.len());
        for t in &tiers {
            std::fs::create_dir_all(&t.root)?;
            let mut steps = BTreeMap::new();
            for entry in std::fs::read_dir(&t.root)? {
                let entry = entry?;
                let p = entry.path();
                if !p.is_dir() {
                    continue;
                }
                let name = entry.file_name().to_string_lossy().into_owned();
                if let Some(step) = parse_step_dirname(&name) {
                    // Only committed directories count; uncommitted
                    // remains of a crash are invisible (and clobbered
                    // on the next save of that step).
                    if let Ok(m) = TierManifest::load(&p) {
                        if m.step == step {
                            steps.insert(step, m.payload_bytes());
                        }
                    }
                }
            }
            resident.push(steps);
        }
        let registry = Arc::new(CopiesRegistry::new(tiers.len() - 1));
        {
            let mut reg = registry.lock();
            for (i, steps) in resident.iter().enumerate() {
                for &s in steps.keys() {
                    reg.record_storage(i, s);
                }
            }
        }
        Ok(Self {
            drain_credits: Arc::new(Backpressure::new(policy.drain_depth() as u64)),
            tiers,
            policy,
            queue_depth: 32,
            host_bp: Arc::new(Backpressure::new(4 * GIB)),
            pool: ThreadPool::new(2),
            inner: Arc::new(Mutex::new(CascadeState {
                resident,
                draining: BTreeSet::new(),
                events: Vec::new(),
                errors: Vec::new(),
            })),
            device: None,
            replica: None,
            erasure: None,
            registry,
            swarm: None,
            delta: None,
            trace: TraceHandle::off(),
        })
    }

    /// Attach a trace sink: every save, drain, eviction, restore and
    /// prefetch emits a lifecycle span (cat `"tier"`), and the cascade's
    /// stall/eviction/fallback counters land in its summary.
    pub fn with_trace(mut self, trace: TraceHandle) -> Self {
        self.trace = trace;
        self
    }

    /// The cascade's trace summary: the handle's spans and counters,
    /// with the component-tracked tallies (registry drops, device and
    /// replica evictions, re-save races) folded in.
    pub fn trace_summary(&self) -> TraceSummary {
        let mut s = self.trace.summary();
        let (sd, rd) = self.registry.drop_counts();
        s.set_counter(Counter::RegistryStorageDrops.name(), sd);
        s.set_counter(Counter::RegistryReplicaDrops.name(), rd);
        if let Some(dev) = &self.device {
            s.set_counter(
                Counter::DeviceEvictions.name(),
                dev.lock().unwrap().eviction_count(),
            );
        }
        if let Some(rt) = &self.replica {
            s.set_counter(Counter::ReplicaEvictions.name(), rt.eviction_count());
            // The handle counts saves that had to wait out an in-flight
            // replication; the tier counts duplicate pending marks.
            // Both are re-save races — report their sum.
            s.set_counter(
                Counter::ReplicaResaveRaces.name(),
                self.trace.counter(Counter::ReplicaResaveRaces) + rt.resave_race_count(),
            );
        }
        if let Some(et) = &self.erasure {
            // The erasure tier keeps its own tallies (it carries no
            // trace handle); the summary is their reporting surface.
            s.set_counter(Counter::ErasureStripEvictions.name(), et.eviction_count());
            s.set_counter(
                Counter::ErasureDegradedRestores.name(),
                et.degraded_restore_count(),
            );
        }
        s
    }

    /// Attach a device tier 0 ([`DeviceStage`]): saves snapshot into HBM
    /// first (newest-*k* pinned) and model the D2H drain feeding the
    /// host pool; restores of a still-pinned step are served from HBM
    /// without touching storage.
    pub fn with_device_stage(mut self, stage: DeviceStage) -> Self {
        self.device = Some(Mutex::new(stage));
        self
    }

    /// Attach an inter-node replica tier ([`ReplicaTier`]): every save
    /// additionally replicates the burst-buffer copy to the tier's
    /// buddy nodes on the cascade's background workers (never on the
    /// caller's critical path), and restores prefer a buddy replica
    /// over the slower storage tiers. A buddy commit counts as a
    /// durable copy for eviction decisions only once acked. The
    /// cascade's [`CopiesRegistry`] is attached to the tier, so both
    /// sides' eviction decisions serialize on one lock.
    pub fn with_replica_tier(mut self, rt: ReplicaTier) -> Self {
        self.replica = Some(Arc::new(rt.with_registry(Arc::clone(&self.registry))));
        self
    }

    /// Attach an erasure-coded stripe tier ([`ErasureTier`]): every
    /// save additionally RS(k,m)-encodes the burst-buffer copy and
    /// distributes one strip per holder node on the cascade's
    /// background workers (never on the caller's critical path), and
    /// restores prefer reconstructing from any k surviving strips over
    /// the slower storage tiers (behind a whole buddy replica, which
    /// needs no gather or decode). The stripe counts as a durable copy
    /// for eviction decisions only while ≥ k strips are committed —
    /// never by raw strip count. The cascade's [`CopiesRegistry`] is
    /// attached to the tier, so both sides' eviction decisions
    /// serialize on one lock.
    pub fn with_erasure(mut self, et: ErasureTier) -> Self {
        self.erasure = Some(Arc::new(et.with_registry(Arc::clone(&self.registry))));
        self
    }

    /// The copies registry shared with the replica tier.
    pub fn registry(&self) -> &Arc<CopiesRegistry> {
        &self.registry
    }

    /// Attach the fleet-wide swarm copies control plane
    /// ([`crate::swarm::SwarmRegistry`]): this cascade runs on node
    /// `node`, and every whole-step tier copy it commits or evicts is
    /// mirrored into the shared registry (the step must be registered
    /// there for the mirror to stick). Restores then consult the
    /// registry's fastest-surviving hint before walking local tiers.
    pub fn with_swarm_registry(
        mut self,
        node: usize,
        reg: Arc<crate::swarm::SwarmRegistry>,
    ) -> Self {
        self.swarm = Some((node, reg));
        self
    }

    /// The attached swarm control plane, if any.
    pub fn swarm_registry(&self) -> Option<&Arc<crate::swarm::SwarmRegistry>> {
        self.swarm.as_ref().map(|(_, r)| r)
    }

    /// Enable delta checkpointing: [`Self::save_delta`] persists only
    /// the chunks whose content hash differs from the previous step's,
    /// writing a full snapshot whenever the chain would exceed
    /// `params.max_chain` (and, with `compact_every > 0`, as a
    /// scheduled keyframe every that many saves). Restores of a
    /// delta-mode step materialize the chain transparently, each
    /// ancestor resolved fastest-surviving-copy-first.
    pub fn with_delta(mut self, params: DeltaParams) -> Self {
        self.delta = Some(Mutex::new(DeltaState {
            params: params.normalized(),
            parent: None,
            chain: Vec::new(),
            saves_since_full: 0,
        }));
        self
    }

    /// The delta knobs, when delta mode is enabled.
    pub fn delta_params(&self) -> Option<DeltaParams> {
        self.delta
            .as_ref()
            .map(|d| d.lock().unwrap().params.clone())
    }

    /// Steps the live delta chain spans (newest first; empty without
    /// delta mode or before the first [`Self::save_delta`]).
    pub fn delta_chain_steps(&self) -> Vec<u64> {
        self.delta
            .as_ref()
            .map(|d| d.lock().unwrap().chain.clone())
            .unwrap_or_default()
    }

    /// The attached replica tier, if any.
    pub fn replica_tier(&self) -> Option<&Arc<ReplicaTier>> {
        self.replica.as_ref()
    }

    /// Steps saved locally but not yet acked by any buddy (0 without a
    /// replica tier) — the durability window a node loss would lose
    /// back to.
    pub fn replication_lag(&self) -> usize {
        self.replica
            .as_ref()
            .map(|rt| rt.replication_lag())
            .unwrap_or(0)
    }

    /// Does any buddy hold a committed replica of `step`?
    pub fn replica_committed_at(&self, step: u64) -> bool {
        self.replica
            .as_ref()
            .is_some_and(|rt| rt.committed_at(step))
    }

    /// The replica tier's event log (empty without one).
    pub fn replica_events(&self) -> Vec<super::replica::ReplicaEvent> {
        self.replica
            .as_ref()
            .map(|rt| rt.events())
            .unwrap_or_default()
    }

    /// The replica tier's (pending, committed) step sets, computed
    /// outside the cascade lock so the two mutexes never nest.
    fn replica_sets(&self) -> (BTreeSet<u64>, BTreeSet<u64>) {
        match &self.replica {
            Some(rt) => (
                rt.pending_steps().into_iter().collect(),
                rt.committed_steps().into_iter().collect(),
            ),
            None => (BTreeSet::new(), BTreeSet::new()),
        }
    }

    /// The attached erasure tier, if any.
    pub fn erasure_tier(&self) -> Option<&Arc<ErasureTier>> {
        self.erasure.as_ref()
    }

    /// Can `step` be reconstructed from the erasure stripe (≥ k strips
    /// committed)? False without an erasure tier.
    pub fn erasure_recoverable_at(&self, step: u64) -> bool {
        self.erasure
            .as_ref()
            .is_some_and(|et| et.recoverable_at(step))
    }

    /// The erasure tier's event log (empty without one).
    pub fn erasure_events(&self) -> Vec<super::erasure::ErasureEvent> {
        self.erasure
            .as_ref()
            .map(|et| et.events())
            .unwrap_or_default()
    }

    /// The erasure tier's (pending, recoverable) step sets, computed
    /// outside the cascade lock so the two mutexes never nest
    /// (mirrors [`Self::replica_sets`]).
    fn erasure_sets(&self) -> (BTreeSet<u64>, BTreeSet<u64>) {
        match &self.erasure {
            Some(et) => (et.pending_steps(), et.recoverable_steps()),
            None => (BTreeSet::new(), BTreeSet::new()),
        }
    }

    /// Is `step`'s snapshot HBM-resident in the device stage?
    pub fn device_resident(&self, step: u64) -> bool {
        self.device
            .as_ref()
            .is_some_and(|d| d.lock().unwrap().contains(step))
    }

    /// Device-resident (pinned) steps, ascending; empty without a
    /// device stage.
    pub fn device_steps(&self) -> Vec<u64> {
        self.device
            .as_ref()
            .map(|d| d.lock().unwrap().resident_steps())
            .unwrap_or_default()
    }

    /// The device stage's event log (empty without a device stage).
    pub fn device_events(&self) -> Vec<super::device::DeviceEvent> {
        self.device
            .as_ref()
            .map(|d| d.lock().unwrap().events())
            .unwrap_or_default()
    }

    /// Pinned host staging budget (default 4 GiB).
    pub fn with_host_budget(mut self, bytes: u64) -> Self {
        self.host_bp = Arc::new(Backpressure::new(bytes.max(1)));
        self
    }

    pub fn with_queue_depth(mut self, qd: u32) -> Self {
        assert!(qd >= 1);
        self.queue_depth = qd;
        self
    }

    pub fn tiers(&self) -> &[TierSpec] {
        &self.tiers
    }

    pub fn policy(&self) -> TierPolicy {
        self.policy
    }

    /// The host staging gate (shared with callers that stage buffers).
    pub fn host_backpressure(&self) -> &Arc<Backpressure> {
        &self.host_bp
    }

    /// Save a checkpoint through the cascade.
    pub fn save(&self, step: u64, data: &[RankData]) -> Result<TierSaveReport> {
        self.save_with(step, data, &|dir| {
            CheckpointStore::new(dir)
                .with_backend(self.tiers[0].backend)
                .save(data)?;
            Ok(None)
        })
    }

    /// Save `step` as a delta against the previous delta-mode save:
    /// only chunks whose content hash changed are staged, written and
    /// fsynced at tier 0, and because the tier manifest then lists only
    /// the journal + packs, every downstream mover — write-back drains,
    /// replica fan-out, swarm seeding — ships only the delta bytes. A
    /// full snapshot is written instead when there is no parent yet,
    /// when the chain would exceed [`DeltaParams::max_chain`], or on
    /// the `compact_every` keyframe schedule.
    pub fn save_delta(&self, step: u64, data: &[RankData]) -> Result<TierSaveReport> {
        let dstate = self
            .delta
            .as_ref()
            .ok_or_else(|| Error::msg("save_delta: enable delta mode with with_delta"))?;
        let (params, parent) = {
            let ds = dstate.lock().unwrap();
            let chain_full = ds.chain.len() >= ds.params.max_chain;
            let keyframe =
                ds.params.compact_every > 0 && ds.saves_since_full >= ds.params.compact_every;
            let parent = if chain_full || keyframe {
                None
            } else {
                ds.parent.clone()
            };
            (ds.params.clone(), parent)
        };
        let store = DeltaStore::new(params).with_backend(self.tiers[0].backend);
        let rep = self.save_with(step, data, &|dir| {
            store.save(dir, step, data, parent.as_ref()).map(Some)
        })?;
        if let Some(d) = &rep.delta {
            self.trace.add(
                Counter::DeltaChunksSkipped,
                (d.chunks_total - d.chunks_written) as u64,
            );
        }
        {
            // Re-read the journal the save just committed: it is the
            // next save's parent, and its parent pointer tells us
            // whether the chain grew or restarted at a full snapshot.
            let j = DeltaJournal::load(&step_dir_of(&self.tiers[0], step))?;
            let mut ds = dstate.lock().unwrap();
            if j.parent.is_none() {
                ds.chain = vec![step];
                ds.saves_since_full = 0;
            } else {
                ds.chain.insert(0, step);
                ds.saves_since_full += 1;
            }
            ds.parent = Some(j);
        }
        Ok(rep)
    }

    /// The shared save path: everything around the tier-0 data write —
    /// admission, room-making, manifest commit, replication, drains —
    /// is identical for full and delta saves; `write` fills the step
    /// directory and reports delta accounting when it has any.
    fn save_with(
        &self,
        step: u64,
        data: &[RankData],
        write: &dyn Fn(&std::path::Path) -> Result<Option<DeltaSaveReport>>,
    ) -> Result<TierSaveReport> {
        let payload: u64 = data
            .iter()
            .map(|d| {
                d.tensors
                    .iter()
                    .map(|(_, b)| b.len() as u64)
                    .sum::<u64>()
            })
            .sum();
        let _save_span = self
            .trace
            .span(SPAN_SAVE, "tier")
            .ctx(0, 0, step)
            .bytes(payload);
        // Tier 0: snapshot into device HBM (newest-k pinned). Admission
        // failure (device OOM) degrades gracefully — the checkpoint
        // simply is not device-resident; the storage path still runs.
        let mut device_resident = false;
        let mut d2h_s = 0.0;
        if let Some(dev) = &self.device {
            let mut stage = dev.lock().unwrap();
            match stage.snapshot(step, data) {
                Ok(rep) => {
                    device_resident = true;
                    d2h_s = rep.d2h_s;
                }
                Err(_) => {
                    d2h_s = stage.d2h_seconds(payload);
                }
            }
            // The D2H drain is modeled virtual time (no real GPU on the
            // path) — emit it as a complete span so sim-time and
            // real-time lanes line up in the same view.
            self.trace.complete(
                Span::new(SPAN_D2H_DRAIN, self.trace.now_us(), (d2h_s * 1e6) as u64)
                    .cat("tier")
                    .step(step)
                    .bytes(payload)
                    .tier("device"),
            );
        }
        // Host pool admission (clamped so an oversized checkpoint still
        // flows — serialized — instead of deadlocking). This is the
        // landing zone of the D2H drain.
        let want = payload.min(self.host_bp.budget());
        let _host = match self.host_bp.try_acquire(want) {
            Ok(g) => g,
            Err(_) => {
                // Would block: the budget is full of still-draining
                // bytes — the stall the backpressure counter surfaces.
                self.trace.bump(Counter::BackpressureStalls);
                self.host_bp.acquire(want)?
            }
        };
        let sw = Stopwatch::start();
        // Re-saving a step whose previous incarnation is still draining
        // (or replicating) would race the pump reading the same
        // directory. The two checks take their locks sequentially —
        // never nested — matching `replica_sets`'s discipline.
        let draining_prev = self.inner.lock().unwrap().draining.contains(&step);
        let replicating_prev = self
            .replica
            .as_ref()
            .is_some_and(|rt| rt.pending_steps().contains(&step));
        let encoding_prev = self
            .erasure
            .as_ref()
            .is_some_and(|et| et.pending_steps().contains(&step));
        if draining_prev || replicating_prev || encoding_prev {
            // A re-save raced its own previous incarnation's background
            // drain/replication; wait the pump out before clobbering.
            self.trace.bump(Counter::ReplicaResaveRaces);
            self.pool.wait_idle();
        }
        self.make_room(0, payload)?;

        let bb_span = self
            .trace
            .span(SPAN_BB_WRITE, "tier")
            .ctx(0, 0, step)
            .bytes(payload)
            .tier(Tier::Storage(0));
        let dir = step_dir_of(&self.tiers[0], step);
        let _ = std::fs::remove_dir_all(&dir); // clobber crash remains
        let delta = write(&dir)?;
        let manifest = TierManifest::from_dir(step, &dir)?
            .with_origin(device_resident.then(|| "device".to_string()));
        self.inner
            .lock()
            .unwrap()
            .events
            .push(TierEvent::DataSynced { tier: 0, step });
        manifest.commit(&dir)?;
        let payload_bytes = manifest.payload_bytes();
        {
            let mut st = self.inner.lock().unwrap();
            st.events.push(TierEvent::ManifestCommitted { tier: 0, step });
            st.resident[0].insert(step, payload_bytes);
        }
        self.registry.lock().record_storage(0, step);
        if let Some((node, sreg)) = &self.swarm {
            if device_resident {
                sreg.record_tier_copy(step, Tier::Device, Some(*node));
            }
            sreg.record_tier_copy(step, Tier::Storage(0), Some(*node));
        }
        drop(bb_span);
        let local_s = sw.elapsed_secs();

        // Enqueue asynchronous replication to the buddy nodes (never on
        // the caller's critical path — DataStates-LLM's constraint).
        if let Some(rt) = &self.replica {
            rt.mark_pending(step);
            let rt = Arc::clone(rt);
            let src_dir = dir.clone();
            let m = manifest.clone();
            let inner = Arc::clone(&self.inner);
            let trace = self.trace.clone();
            let swarm = self.swarm.clone();
            self.pool.execute(move || {
                let mut rep_span = trace
                    .span(SPAN_REPLICATE, "tier")
                    .ctx(0, 0, step)
                    .bytes(m.payload_bytes());
                // The replica tier carries the cascade's copies
                // registry (attached by `with_replica_tier`), so its
                // budget-eviction decisions read "durable on the
                // slowest tier" under the same lock a concurrent PFS
                // eviction must take — the one-lock protocol that
                // closes the old PFS-evict/replica-evict race window.
                // The legacy durable-snapshot argument is therefore
                // empty here; it only gates registry-less tiers.
                match rt.replicate(step, &src_dir, &m, &[]) {
                    Ok(rep) => {
                        if let Some(&b) = rep.acked.first() {
                            rep_span.set_tier(Tier::Replica(b));
                        }
                        if let Some((_, sreg)) = &swarm {
                            for &b in &rep.acked {
                                sreg.record_tier_copy(step, Tier::Replica(b), Some(b));
                            }
                        }
                        // Partial success (some buddies failed) must
                        // surface through flush(), not vanish — an
                        // operator counting on fan-out-k protection
                        // needs to hear that k was not reached.
                        let mut st = inner.lock().unwrap();
                        for e in rep.errors {
                            st.errors
                                .push(format!("replicate step {step} (partial): {e}"));
                        }
                    }
                    Err(e) => {
                        inner
                            .lock()
                            .unwrap()
                            .errors
                            .push(format!("replicate step {step}: {e}"));
                    }
                }
            });
        }

        // Enqueue the asynchronous RS(k,m) encode + strip distribution
        // (same off-critical-path rule as replication: the caller never
        // pays the GF(2^8) encode or the k+m fan-out).
        if let Some(et) = &self.erasure {
            et.mark_pending(step);
            let et = Arc::clone(et);
            let src_dir = dir.clone();
            let m = manifest.clone();
            let inner = Arc::clone(&self.inner);
            let trace = self.trace.clone();
            let swarm = self.swarm.clone();
            self.pool.execute(move || {
                let _enc_span = trace
                    .span(SPAN_ERASURE_ENCODE, "tier")
                    .ctx(0, 0, step)
                    .bytes(m.payload_bytes());
                // The erasure tier carries the cascade's copies
                // registry (attached by `with_erasure`), so its strip
                // evictions read "durable on the slowest tier" under
                // the same lock as every other eviction decision; the
                // legacy durable-snapshot argument is empty here.
                match et.encode_and_distribute(step, &src_dir, &m, &[]) {
                    Ok(rep) => {
                        trace.add(Counter::ErasureStripsWritten, rep.acked.len() as u64);
                        trace.add(Counter::ErasureParityBytes, rep.parity_bytes);
                        if let Some((_, sreg)) = &swarm {
                            // Strip holders are published as *strips*,
                            // never as whole-step copies: the swarm
                            // hint may name `Tier::Erasure` only once
                            // ≥ k of them are reachable.
                            let k = et.params().k;
                            for &(_, holder) in &rep.acked {
                                sreg.record_strip_copy(step, holder, k);
                            }
                        }
                        // Partial success (k..k+m-1 strips) restores
                        // but sits below the configured loss margin —
                        // surface it through flush(), not silently.
                        let mut st = inner.lock().unwrap();
                        for e in rep.errors {
                            st.errors
                                .push(format!("erasure encode step {step} (partial): {e}"));
                        }
                    }
                    Err(e) => {
                        inner
                            .lock()
                            .unwrap()
                            .errors
                            .push(format!("erasure encode step {step}: {e}"));
                    }
                }
            });
        }

        let mut drained_sync = false;
        if self.tiers.len() > 1 && self.policy.propagates(step) {
            if self.policy == TierPolicy::WriteThrough {
                let _flush_span = self
                    .trace
                    .span(SPAN_PFS_FLUSH, "tier")
                    .ctx(0, 0, step)
                    .bytes(payload_bytes)
                    .tier(Tier::Storage(self.tiers.len() - 1));
                drain_chain(
                    &self.tiers,
                    &self.inner,
                    &self.registry,
                    self.queue_depth,
                    step,
                    &manifest,
                )?;
                self.mirror_drained_tiers(step);
                drained_sync = true;
            } else {
                self.enqueue_drain(step, manifest)?;
            }
        }
        Ok(TierSaveReport {
            step,
            payload_bytes,
            blocking_s: sw.elapsed_secs(),
            local_s,
            drained_sync,
            device_resident,
            d2h_s,
            delta,
        })
    }

    /// Mirror the whole-step copies the upward drain just committed
    /// (every tier past the burst buffer) into the swarm control
    /// plane; the slowest tier is the shared PFS, so its copy carries
    /// no node.
    fn mirror_drained_tiers(&self, step: u64) {
        if let Some((node, sreg)) = &self.swarm {
            let last = self.tiers.len() - 1;
            for i in 1..self.tiers.len() {
                let on = if i == last { None } else { Some(*node) };
                sreg.record_tier_copy(step, Tier::Storage(i), on);
            }
        }
    }

    /// Queue an asynchronous upward drain, blocking if `drain_depth`
    /// checkpoints are already queued or in flight.
    fn enqueue_drain(&self, step: u64, manifest: TierManifest) -> Result<()> {
        let credit = match self.drain_credits.try_acquire_owned(1) {
            Ok(c) => c,
            Err(_) => {
                self.trace.bump(Counter::BackpressureStalls);
                self.drain_credits.acquire_owned(1)?
            }
        };
        self.inner.lock().unwrap().draining.insert(step);
        let tiers = self.tiers.clone();
        let inner = Arc::clone(&self.inner);
        let registry = Arc::clone(&self.registry);
        let qd = self.queue_depth;
        let trace = self.trace.clone();
        let dst = self.tiers.len() - 1;
        let swarm = self.swarm.clone();
        self.pool.execute(move || {
            let res = {
                let _flush_span = trace
                    .span(SPAN_PFS_FLUSH, "tier")
                    .ctx(0, 0, step)
                    .bytes(manifest.payload_bytes())
                    .tier(Tier::Storage(dst));
                drain_chain(&tiers, &inner, &registry, qd, step, &manifest)
            };
            if res.is_ok() {
                if let Some((node, sreg)) = &swarm {
                    for i in 1..tiers.len() {
                        let on = (i != dst).then_some(*node);
                        sreg.record_tier_copy(step, Tier::Storage(i), on);
                    }
                }
            }
            let mut st = inner.lock().unwrap();
            st.draining.remove(&step);
            if let Err(e) = res {
                st.errors.push(format!("drain step {step}: {e}"));
            }
            drop(st);
            drop(credit);
        });
        Ok(())
    }

    /// Block until all queued drains and prefetches finished; surfaces
    /// any background errors.
    pub fn flush(&self) -> Result<()> {
        self.pool.wait_idle();
        let errors = std::mem::take(&mut self.inner.lock().unwrap().errors);
        if errors.is_empty() {
            Ok(())
        } else {
            Err(Error::msg(format!("tier drains failed: {}", errors.join("; "))))
        }
    }

    /// Evict `step`'s copy at `tier`. Refuses if it is the sole durable
    /// copy with nothing newer (that would silently lose the latest
    /// checkpoint) or if the step is still draining — or replicating —
    /// out of tier 0. An *acked* buddy replica counts as a durable copy
    /// elsewhere; a merely pending one does not ("buddy commit acked
    /// before eligible for eviction").
    ///
    /// The whole decision + removal runs under the copies-registry
    /// lock, so it serializes against the replica tier's eviction
    /// decisions ([`ReplicaTier`]'s budget eviction reads "durable on
    /// the PFS" under the same lock) — the single-lock protocol that
    /// closes the old PFS-evict/replica-evict race window.
    pub fn evict(&self, tier: usize, step: u64) -> Result<()> {
        // Snapshot outside the registry/cascade locks (the delta mutex
        // is leaf-level and never nests with them).
        let live_chain = self.delta_chain_steps().contains(&step);
        let mut reg = self.registry.lock();
        let (rep_pending, rep_committed) = self.replica_sets();
        let (ec_pending, _) = self.erasure_sets();
        {
            let st = self.inner.lock().unwrap();
            if tier == 0
                && (st.draining.contains(&step)
                    || rep_pending.contains(&step)
                    || ec_pending.contains(&step))
            {
                return Err(Error::msg(format!(
                    "step {step}: drain, replication or erasure encode in flight; cannot evict"
                )));
            }
            // A reconstructible stripe (≥ k strips committed, checked
            // under the registry lock — never a raw strip count) is a
            // surviving copy; a lone strip holder is not.
            let elsewhere = st
                .resident
                .iter()
                .enumerate()
                .any(|(i, m)| i != tier && m.contains_key(&step))
                || rep_committed.contains(&step)
                || reg.erasure_recoverable(step);
            let newer_here = st.resident[tier]
                .keys()
                .next_back()
                .is_some_and(|&n| n > step);
            if !elsewhere && !newer_here {
                return Err(Error::msg(format!(
                    "step {step}: sole durable copy lives at tier {tier}; refusing to evict"
                )));
            }
            // A newer step existing is no licence to drop a live delta
            // chain member's last copy — the head's inherited chunks
            // still point into it.
            if !elsewhere && live_chain {
                return Err(Error::msg(format!(
                    "step {step}: sole copy of a live delta-chain member; refusing to evict"
                )));
            }
        }
        let mut evict_span = self
            .trace
            .span(SPAN_EVICT, "tier")
            .ctx(0, 0, step)
            .tier(Tier::Storage(tier));
        // Rename the victim aside under the lock (cheap, atomic, and
        // invisible to manifest loads and recovery scans — the step
        // dirname no longer parses), then do the slow recursive delete
        // after the registry lock drops so concurrent saves recording
        // commits never serialize behind filesystem deletion.
        let dir = step_dir_of(&self.tiers[tier], step);
        let doomed = if dir.exists() {
            let tmp = dir.with_extension("evicting");
            let _ = std::fs::remove_dir_all(&tmp); // stale remains
            std::fs::rename(&dir, &tmp)?;
            Some(tmp)
        } else {
            None
        };
        {
            let mut st = self.inner.lock().unwrap();
            if let Some(bytes) = st.resident[tier].remove(&step) {
                evict_span.set_bytes(bytes);
            }
            st.events.push(TierEvent::Evicted { tier, step });
        }
        reg.drop_storage(tier, step);
        drop(reg);
        if let Some((_, sreg)) = &self.swarm {
            sreg.drop_tier_copy(step, Tier::Storage(tier));
        }
        if let Some(tmp) = doomed {
            std::fs::remove_dir_all(&tmp)?;
        }
        self.trace.bump(Counter::StorageEvictions);
        Ok(())
    }

    /// Evict committed checkpoints from `tier` until `incoming` more
    /// bytes (plus padding slack) fit its capacity.
    fn make_room(&self, tier: usize, incoming: u64) -> Result<()> {
        let cap = self.tiers[tier].capacity;
        if cap == u64::MAX {
            return Ok(());
        }
        // Store padding + headers + sidecar slack.
        let need = incoming + incoming / 8 + (1 << 20);
        // Live delta-chain members are only victims when another copy
        // survives elsewhere — mirrors the guard in `evict`.
        let chain = self.delta_chain_steps();
        for attempt in 0..2 {
            loop {
                let victim = {
                    // Replica and erasure state first, then the cascade
                    // lock — the mutexes never nest.
                    let (rep_pending, rep_committed) = self.replica_sets();
                    let (ec_pending, ec_recoverable) = self.erasure_sets();
                    let st = self.inner.lock().unwrap();
                    let used: u64 = st.resident[tier].values().sum();
                    if used.saturating_add(need) <= cap {
                        return Ok(());
                    }
                    let newest = st.resident[tier].keys().next_back().copied();
                    st.resident[tier]
                        .iter()
                        .map(|(s, _)| *s)
                        .find(|s| {
                            let elsewhere = st
                                .resident
                                .iter()
                                .enumerate()
                                .any(|(i, m)| i != tier && m.contains_key(s))
                                || rep_committed.contains(s)
                                || ec_recoverable.contains(s);
                            let obsolete =
                                newest.is_some_and(|n| n > *s) && !chain.contains(s);
                            !st.draining.contains(s)
                                && !rep_pending.contains(s)
                                && !ec_pending.contains(s)
                                && (elsewhere || obsolete)
                        })
                };
                match victim {
                    Some(s) => self.evict(tier, s)?,
                    None => break,
                }
            }
            if attempt == 0 {
                // In-flight drains may be holding eviction back.
                self.pool.wait_idle();
            }
        }
        self.trace.bump(Counter::MakeRoomRejections);
        Err(Error::msg(format!(
            "tier {} ({}): {} bytes will not fit capacity {}",
            tier, self.tiers[tier].name, need, cap
        )))
    }

    /// Restore `step`, walking the copies fastest-first — the device
    /// stage (if attached and still holding the step), then the burst
    /// buffer, then a buddy node's peer replica, then the erasure
    /// stripe (reconstructed from any k surviving strips), then the
    /// slower storage tiers; returns the data and the [`Tier`] it was
    /// served from. A copy that is missing or fails verification is
    /// skipped — the fastest *surviving* copy wins.
    pub fn restore(&self, step: u64) -> Result<(Vec<RankData>, Tier)> {
        self.restore_via(step, &Ok, &|dir, t| {
            CheckpointStore::new(dir).with_backend(t.backend).load()
        })
    }

    /// Elastic restore: serve `step` resharded onto `target` — the
    /// fastest-surviving-copy walk of [`Self::restore`] (device → bb →
    /// buddy replica → slower tiers), with each copy resharded on the
    /// way out. Copies already in memory (device HBM snapshots, buddy
    /// replicas) reshard in memory; storage tiers go through the
    /// extent read planner, so a PFS-served elastic restore issues
    /// coalesced large reads instead of naive per-shard ones.
    pub fn restore_elastic(
        &self,
        step: u64,
        target: crate::workload::Parallelism,
        planner: &crate::reshard::ReadPlanner,
    ) -> Result<(Vec<RankData>, Tier)> {
        use crate::reshard::elastic::{elastic_restore, reshard_data};
        use crate::reshard::index::ShardIndex;
        self.restore_via(
            step,
            &|data| reshard_data(&data, target),
            &|dir, t| {
                let _reshard_span =
                    self.trace.span(SPAN_RESHARD_READ, "reshard").ctx(0, 0, step);
                ShardIndex::from_store(dir)
                    .and_then(|idx| elastic_restore(dir, &idx, target, planner, t.backend))
            },
        )
    }

    /// Traced entry point over [`Self::restore_walk`]: wraps the walk
    /// in a [`SPAN_RESTORE`] span tagged with the serving tier and
    /// payload bytes, and counts (plus warns about) restores that had
    /// to fall past the fast copies — anything slower than the device
    /// stage or the burst buffer means the fastest copy was lost or
    /// failed verification.
    fn restore_via(
        &self,
        step: u64,
        from_memory: &dyn Fn(Vec<RankData>) -> Result<Vec<RankData>>,
        from_dir: &dyn Fn(&std::path::Path, &TierSpec) -> Result<Vec<RankData>>,
    ) -> Result<(Vec<RankData>, Tier)> {
        let mut span = self.trace.span(SPAN_RESTORE, "tier").ctx(0, 0, step);
        let (data, tier) = self.restore_walk(step, from_memory, from_dir)?;
        let bytes: u64 = data
            .iter()
            .flat_map(|r| r.tensors.iter())
            .map(|(_, t)| t.len() as u64)
            .sum();
        span.set_bytes(bytes);
        span.set_tier(tier);
        if !matches!(tier, Tier::Device | Tier::Storage(0)) {
            self.trace.bump(Counter::FallbackRestores);
            log::warn!("step {step}: fastest copy gone; restore served from {tier}");
        }
        Ok((data, tier))
    }

    /// The shared fastest-surviving-copy walk behind [`Self::restore`]
    /// and [`Self::restore_elastic`]: `from_memory` materializes a copy
    /// that is already loaded (device HBM snapshot, buddy replica);
    /// `from_dir` serves a tier directory whose manifest verified.
    fn restore_walk(
        &self,
        step: u64,
        from_memory: &dyn Fn(Vec<RankData>) -> Result<Vec<RankData>>,
        from_dir: &dyn Fn(&std::path::Path, &TierSpec) -> Result<Vec<RankData>>,
    ) -> Result<(Vec<RankData>, Tier)> {
        if let Some(dev) = &self.device {
            if let Some((data, _h2d_s)) = dev.lock().unwrap().fetch(step) {
                return Ok((from_memory(data)?, Tier::Device));
            }
        }
        // The fleet control plane may know the fastest surviving copy
        // is a buddy replica (e.g. this node's burst buffer was lost)
        // or the erasure stripe (whole copies gone, ≥ k strips left):
        // jump the storage walk straight to it.
        let hint = self
            .swarm
            .as_ref()
            .and_then(|(_, sreg)| sreg.fastest_surviving(step));
        let replica_hinted = matches!(hint, Some(Tier::Replica(_)));
        let erasure_hinted = hint == Some(Tier::Erasure);
        let mut last_err: Option<Error> = None;
        let try_replica = |last_err: &mut Option<Error>| -> Option<(Vec<RankData>, Tier)> {
            let rt = self.replica.as_ref()?;
            match self.replica_fetch(rt, step) {
                Ok((data, buddy)) => match from_memory(data) {
                    Ok(d) => Some((d, Tier::Replica(buddy))),
                    Err(e) => {
                        *last_err = Some(e);
                        None
                    }
                },
                Err(e) => {
                    // Only surface the error when a replica was
                    // expected; "never replicated" is not a failure.
                    if rt.committed_at(step) {
                        *last_err = Some(e);
                    }
                    None
                }
            }
        };
        // The erasure stripe ranks behind a whole buddy replica (a
        // gather of k strips plus a possible decode is slower than one
        // fabric read) but ahead of every tier slower than the burst
        // buffer.
        let try_erasure = |last_err: &mut Option<Error>| -> Option<(Vec<RankData>, Tier)> {
            let et = self.erasure.as_ref()?;
            match self.erasure_fetch(et, step) {
                Ok(data) => match from_memory(data) {
                    Ok(d) => Some((d, Tier::Erasure)),
                    Err(e) => {
                        *last_err = Some(e);
                        None
                    }
                },
                Err(e) => {
                    // Only surface the error when the stripe was
                    // expected to reconstruct; "never encoded" or
                    // "below k survivors" is reported by the walk's
                    // final error if nothing else serves.
                    if et.recoverable_at(step) {
                        *last_err = Some(e);
                    }
                    None
                }
            }
        };
        let mut replica_tried = false;
        let mut erasure_tried = false;
        if replica_hinted {
            replica_tried = true;
            if let Some(hit) = try_replica(&mut last_err) {
                return Ok(hit);
            }
        }
        if erasure_hinted {
            erasure_tried = true;
            if let Some(hit) = try_erasure(&mut last_err) {
                return Ok(hit);
            }
        }
        for (i, t) in self.tiers.iter().enumerate() {
            // The peer replica outranks every tier slower than the
            // burst buffer; the erasure stripe follows right behind it.
            if i == 1 && !replica_tried {
                replica_tried = true;
                if let Some(hit) = try_replica(&mut last_err) {
                    return Ok(hit);
                }
            }
            if i == 1 && !erasure_tried {
                erasure_tried = true;
                if let Some(hit) = try_erasure(&mut last_err) {
                    return Ok(hit);
                }
            }
            let dir = step_dir_of(t, step);
            let m = match TierManifest::load(&dir) {
                Ok(m) if m.step == step => m,
                _ => continue,
            };
            if let Err(e) = m.verify(&dir) {
                last_err = Some(e);
                continue;
            }
            // A delta-mode directory holds a journal + packs, not store
            // blobs: materialize through the parent chain, each
            // ancestor resolved fastest-surviving-copy-first, then hand
            // the in-memory state to `from_memory` — the same path
            // device snapshots and buddy replicas take, so elastic
            // restores reshard the materialized state bit-identically.
            if DeltaJournal::is_delta_dir(&dir) {
                let res = DeltaStore::restore_dir(&dir, &|p| self.ancestor_dir(p))
                    .and_then(|d| from_memory(d));
                match res {
                    Ok(data) => return Ok((data, Tier::Storage(i))),
                    Err(e) => last_err = Some(e),
                }
                continue;
            }
            match from_dir(&dir, t) {
                Ok(data) => return Ok((data, Tier::Storage(i))),
                Err(e) => last_err = Some(e),
            }
        }
        // A single-tier cascade never reaches index 1: the replica and
        // the erasure stripe are still the fallbacks behind it.
        if !replica_tried {
            if let Some(hit) = try_replica(&mut last_err) {
                return Ok(hit);
            }
        }
        if !erasure_tried {
            if let Some(hit) = try_erasure(&mut last_err) {
                return Ok(hit);
            }
        }
        Err(last_err.unwrap_or_else(|| {
            Error::msg(format!("step {step}: not committed at any tier"))
        }))
    }

    /// Fetch `step` from the erasure stripe: gather any k surviving
    /// strips, reconstruct the step's original blobs into a committed
    /// directory, and load it — the delta-aware path when the encoded
    /// step was a delta save (the stripe then carries journal + packs,
    /// and the chain materializes through [`Self::ancestor_dir`]).
    fn erasure_fetch(&self, et: &ErasureTier, step: u64) -> Result<Vec<RankData>> {
        let mut span = self
            .trace
            .span(SPAN_ERASURE_DECODE, "tier")
            .ctx(0, 0, step);
        let (dir, _survivors, _degraded) = et.reconstruct_dir(et.node(), step)?;
        span.set_tier(Tier::Erasure);
        if DeltaJournal::is_delta_dir(&dir) {
            DeltaStore::restore_dir(&dir, &|p| self.ancestor_dir(p))
        } else {
            CheckpointStore::new(&dir)
                .with_backend(self.tiers[0].backend)
                .load()
        }
    }

    /// Fetch `step` from a buddy replica: the plain full-store load,
    /// falling back to materializing a delta-mode replica (the buddies
    /// hold only journal + packs) through the chain when delta mode is
    /// on.
    fn replica_fetch(&self, rt: &ReplicaTier, step: u64) -> Result<(Vec<RankData>, usize)> {
        let err = match rt.restore(step) {
            Ok(hit) => return Ok(hit),
            Err(e) => e,
        };
        if self.delta.is_none() {
            return Err(err);
        }
        let mut last = err;
        for buddy in rt.acked_buddies(step) {
            let dir = rt.store_dir(rt.node(), buddy, step);
            if !DeltaJournal::is_delta_dir(&dir) {
                continue;
            }
            match DeltaStore::restore_dir(&dir, &|p| self.ancestor_dir(p)) {
                Ok(data) => return Ok((data, buddy)),
                Err(e) => last = e,
            }
        }
        Err(last)
    }

    /// Resolve a delta-chain ancestor to its fastest surviving
    /// committed directory: the burst buffer first, then acked buddy
    /// replicas, then the slower storage tiers — the same precedence
    /// [`Self::restore_walk`] gives whole steps. The chunk reads that
    /// follow verify content hashes, so a stale or torn copy fails
    /// loudly rather than silently serving drifted bytes.
    fn ancestor_dir(&self, step: u64) -> Result<PathBuf> {
        let mut candidates = vec![step_dir_of(&self.tiers[0], step)];
        if let Some(rt) = &self.replica {
            for buddy in rt.acked_buddies(step) {
                candidates.push(rt.store_dir(rt.node(), buddy, step));
            }
        }
        for t in &self.tiers[1..] {
            candidates.push(step_dir_of(t, step));
        }
        for dir in candidates {
            if TierManifest::load(&dir).is_ok_and(|m| m.step == step) {
                return Ok(dir);
            }
        }
        // Last resort: reconstruct the ancestor from its erasure
        // stripe (any k surviving strips re-materialize the committed
        // directory the chunk reads then verify against).
        if let Some(et) = &self.erasure {
            if let Ok((dir, _, _)) = et.reconstruct_dir(et.node(), step) {
                return Ok(dir);
            }
        }
        Err(Error::msg(format!(
            "delta chain: ancestor step {step} not committed at any tier, replica or stripe"
        )))
    }

    /// Fold `step`'s delta chain into a full snapshot, in place, at
    /// every tier holding a committed delta copy (fastest first), and
    /// re-commit each tier's manifest over the folded file set — the
    /// background compaction bounding restore cost by chain length.
    /// Crash-safe and idempotent (see [`crate::ckpt::delta::compact`]).
    /// Returns `true` when any tier was folded. Refuses while the step
    /// is draining or replicating — the background pump reads the very
    /// files compaction garbage-collects.
    pub fn compact_delta(&self, step: u64) -> Result<bool> {
        let dstate = self
            .delta
            .as_ref()
            .ok_or_else(|| Error::msg("compact_delta: delta mode not enabled"))?;
        let draining = self.inner.lock().unwrap().draining.contains(&step);
        let replicating = self
            .replica
            .as_ref()
            .is_some_and(|rt| rt.pending_steps().contains(&step));
        let encoding = self
            .erasure
            .as_ref()
            .is_some_and(|et| et.pending_steps().contains(&step));
        if draining || replicating || encoding {
            return Err(Error::msg(format!(
                "step {step}: drain, replication or erasure encode in flight; cannot compact"
            )));
        }
        let params = dstate.lock().unwrap().params.clone();
        let mut any = false;
        for (i, t) in self.tiers.iter().enumerate() {
            let committed = self.inner.lock().unwrap().resident[i].contains_key(&step);
            let dir = step_dir_of(t, step);
            if !committed || !DeltaJournal::is_delta_dir(&dir) {
                continue;
            }
            let store = DeltaStore::new(params.clone()).with_backend(t.backend);
            if crate::ckpt::delta::compact(&store, &dir, &|p| self.ancestor_dir(p))? {
                any = true;
            }
            // The folded copy's payload (a full snapshot) replaces the
            // delta payload in the residency accounting.
            let m = TierManifest::load(&dir)?;
            self.inner
                .lock()
                .unwrap()
                .resident[i]
                .insert(step, m.payload_bytes());
        }
        if any {
            self.trace.bump(Counter::DeltaCompactions);
        }
        // If the folded step was the chain head, the next save's parent
        // is the folded full-snapshot journal and the chain restarts.
        let mut ds = dstate.lock().unwrap();
        if ds.parent.as_ref().is_some_and(|j| j.step == step) {
            let dir0 = step_dir_of(&self.tiers[0], step);
            if DeltaJournal::is_delta_dir(&dir0) {
                ds.parent = Some(DeltaJournal::load(&dir0)?);
            }
            ds.chain = vec![step];
            ds.saves_since_full = 0;
        }
        Ok(any)
    }

    /// Restore the newest checkpoint (device-resident snapshots, buddy
    /// replicas and reconstructible erasure stripes count).
    pub fn restore_latest(&self) -> Result<(u64, Vec<RankData>, Tier)> {
        let step = {
            let st = self.inner.lock().unwrap();
            st.resident
                .iter()
                .flat_map(|m| m.keys())
                .max()
                .copied()
        };
        let replica_latest = self.replica.as_ref().and_then(|rt| rt.latest_step());
        let erasure_latest = self
            .erasure
            .as_ref()
            .and_then(|et| et.latest_recoverable_step());
        let step = self
            .device_steps()
            .last()
            .copied()
            .into_iter()
            .chain(step)
            .chain(replica_latest)
            .chain(erasure_latest)
            .max();
        match step {
            Some(s) => self.restore(s).map(|(d, t)| (s, d, t)),
            None => Err(Error::msg("no committed checkpoints in the cascade")),
        }
    }

    /// Pull `step` from a slower tier back into tier 0 in the
    /// background (restore prefetch). No-op if already resident there;
    /// best-effort: silently skipped when the burst buffer lacks room
    /// (a skipped prefetch only costs the overlap — restore falls
    /// through to the slower tier). When no slower *storage* tier
    /// holds the step but a buddy replica does, the replica store is
    /// the source — the replacement-node path: after a rebuilt node's
    /// replica-served restore, a prefetch pulls the buddy copy back
    /// into the node's burst buffer on the background workers, so the
    /// next restore hits tier 0 at NVMe speed.
    pub fn prefetch(&self, step: u64) -> Result<()> {
        let src_tier = {
            let st = self.inner.lock().unwrap();
            if st.resident[0].contains_key(&step) {
                return Ok(());
            }
            (1..self.tiers.len()).find(|&i| st.resident[i].contains_key(&step))
        };
        let tiers = self.tiers.clone();
        let inner = Arc::clone(&self.inner);
        let registry = Arc::clone(&self.registry);
        let qd = self.queue_depth;
        let trace = self.trace.clone();
        if let Some(j) = src_tier {
            self.pool.execute(move || {
                let mut pf_span = trace
                    .span(SPAN_PREFETCH, "tier")
                    .ctx(0, 0, step)
                    .tier(Tier::Storage(j));
                let res = (|| -> Result<()> {
                    let src_dir = step_dir_of(&tiers[j], step);
                    let manifest = TierManifest::load(&src_dir)?;
                    pf_span.set_bytes(manifest.payload_bytes());
                    // Capacity check (best-effort): never push the burst
                    // buffer past its budget for a prefetch.
                    if !burst_has_room(&tiers, &inner, manifest.payload_bytes()) {
                        return Ok(());
                    }
                    promote(
                        &tiers[j],
                        &tiers[0],
                        0,
                        step,
                        &manifest,
                        qd,
                        &inner,
                        &registry,
                    )?;
                    inner
                        .lock()
                        .unwrap()
                        .events
                        .push(TierEvent::Prefetched { tier: 0, step });
                    Ok(())
                })();
                if let Err(e) = res {
                    inner
                        .lock()
                        .unwrap()
                        .errors
                        .push(format!("prefetch step {step}: {e}"));
                }
            });
            return Ok(());
        }
        // Replica-aware prefetch: no storage tier has it — a buddy may.
        let rt = match &self.replica {
            Some(rt) if rt.committed_at(step) => Arc::clone(rt),
            _ => {
                return Err(Error::msg(format!(
                    "step {step}: not committed at any tier; nothing to prefetch"
                )))
            }
        };
        self.pool.execute(move || {
            let mut pf_span = trace.span(SPAN_PREFETCH, "tier").ctx(0, 0, step);
            let res = (|| -> Result<()> {
                let mut last: Option<Error> = None;
                for buddy in rt.acked_buddies(step) {
                    let src = rt.store_dir(rt.node(), buddy, step);
                    let manifest = match TierManifest::load(&src) {
                        Ok(m) if m.step == step => m,
                        _ => continue,
                    };
                    pf_span.set_tier(Tier::Replica(buddy));
                    pf_span.set_bytes(manifest.payload_bytes());
                    if let Err(e) = manifest.verify(&src) {
                        last = Some(e);
                        continue;
                    }
                    if !burst_has_room(&tiers, &inner, manifest.payload_bytes()) {
                        return Ok(());
                    }
                    let _ = std::fs::remove_dir_all(step_dir_of(&tiers[0], step));
                    // The rebuilt burst-buffer copy is a primary again.
                    let m0 = manifest.with_replica_of(None);
                    land_at_tier(
                        &src,
                        tiers[0].backend,
                        &tiers[0],
                        0,
                        step,
                        &m0,
                        qd,
                        &inner,
                        &registry,
                    )?;
                    inner
                        .lock()
                        .unwrap()
                        .events
                        .push(TierEvent::Prefetched { tier: 0, step });
                    return Ok(());
                }
                Err(last.unwrap_or_else(|| {
                    Error::msg(format!(
                        "step {step}: no verifying buddy replica to prefetch"
                    ))
                }))
            })();
            if let Err(e) = res {
                inner
                    .lock()
                    .unwrap()
                    .errors
                    .push(format!("replica prefetch step {step}: {e}"));
            }
        });
        Ok(())
    }

    /// Is `step` durable (manifest committed) at `tier`?
    pub fn committed_at(&self, tier: usize, step: u64) -> bool {
        self.inner.lock().unwrap().resident[tier].contains_key(&step)
    }

    /// Committed steps at `tier`, ascending.
    pub fn resident_steps(&self, tier: usize) -> Vec<u64> {
        self.inner.lock().unwrap().resident[tier]
            .keys()
            .copied()
            .collect()
    }

    /// Committed payload bytes at `tier`.
    pub fn resident_bytes(&self, tier: usize) -> u64 {
        self.inner.lock().unwrap().resident[tier].values().sum()
    }

    /// The event log so far (clone; the cascade keeps accumulating).
    pub fn events(&self) -> Vec<TierEvent> {
        self.inner.lock().unwrap().events.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ckpt::lean;
    use crate::util::prng::Xoshiro256;

    fn data(rank: usize, bytes: usize, seed: u64) -> RankData {
        let mut rng = Xoshiro256::seeded(seed);
        let mut b = vec![0u8; bytes];
        rng.fill_bytes(&mut b);
        RankData {
            rank,
            tensors: vec![(format!("t{rank}"), b)],
            lean: lean::training_state(1, 1e-3, "cascade"),
        }
    }

    fn two_tier(name: &str, policy: TierPolicy) -> (TierCascade, PathBuf) {
        let base = std::env::temp_dir().join(format!(
            "ckptio-casc-{name}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&base);
        let tiers = vec![
            TierSpec::new("bb", base.join("bb")).with_backend(BackendKind::Posix),
            TierSpec::new("pfs", base.join("pfs")).with_backend(BackendKind::Posix),
        ];
        (TierCascade::new(tiers, policy).unwrap(), base)
    }

    #[test]
    fn writeback_save_commits_locally_then_drains() {
        let (c, base) = two_tier("wb", TierPolicy::WriteBack { drain_depth: 2 });
        let rep = c.save(1, &[data(0, 50_000, 1)]).unwrap();
        assert!(rep.payload_bytes > 0);
        assert!(c.committed_at(0, 1));
        c.flush().unwrap();
        assert!(c.committed_at(1, 1), "drained to pfs tier");
        let (back, tier) = c.restore(1).unwrap();
        assert_eq!(tier, Tier::Storage(0), "restore served from the burst buffer");
        assert_eq!(back[0].tensors, data(0, 50_000, 1).tensors);
        std::fs::remove_dir_all(&base).unwrap();
    }

    #[test]
    fn writethrough_is_synchronous() {
        let (c, base) = two_tier("wt", TierPolicy::WriteThrough);
        let rep = c.save(5, &[data(0, 10_000, 5)]).unwrap();
        assert!(rep.drained_sync);
        assert!(c.committed_at(0, 5) && c.committed_at(1, 5));
        std::fs::remove_dir_all(&base).unwrap();
    }

    #[test]
    fn local_only_every_k_drains_kth() {
        let (c, base) = two_tier("k", TierPolicy::LocalOnlyEveryK { k: 2 });
        for step in 1..=4 {
            c.save(step, &[data(0, 8_000, step)]).unwrap();
        }
        c.flush().unwrap();
        assert!(c.committed_at(0, 1) && c.committed_at(0, 3));
        assert!(!c.committed_at(1, 1) && !c.committed_at(1, 3));
        assert!(c.committed_at(1, 2) && c.committed_at(1, 4));
        std::fs::remove_dir_all(&base).unwrap();
    }

    #[test]
    fn evict_refuses_sole_latest_copy() {
        let (c, base) = two_tier("sole", TierPolicy::LocalOnlyEveryK { k: 100 });
        c.save(1, &[data(0, 4_000, 1)]).unwrap();
        c.flush().unwrap();
        let err = c.evict(0, 1).unwrap_err();
        assert!(err.to_string().contains("sole durable copy"), "{err}");
        // A newer checkpoint makes the old local-only one evictable.
        c.save(2, &[data(0, 4_000, 2)]).unwrap();
        c.flush().unwrap();
        c.evict(0, 1).unwrap();
        assert!(!c.committed_at(0, 1));
        std::fs::remove_dir_all(&base).unwrap();
    }

    #[test]
    fn restore_latest_finds_newest() {
        let (c, base) = two_tier("latest", TierPolicy::WriteBack { drain_depth: 1 });
        c.save(3, &[data(0, 6_000, 3)]).unwrap();
        c.save(9, &[data(0, 6_000, 9)]).unwrap();
        c.flush().unwrap();
        let (step, back, _) = c.restore_latest().unwrap();
        assert_eq!(step, 9);
        assert_eq!(back[0].tensors, data(0, 6_000, 9).tensors);
        std::fs::remove_dir_all(&base).unwrap();
    }

    #[test]
    fn device_stage_serves_pinned_restores_and_reports_d2h() {
        let (c, base) = two_tier("dev", TierPolicy::WriteBack { drain_depth: 2 });
        let c = c.with_device_stage(DeviceStage::new(1 << 20, 2).with_pcie_bw(1e9, 1e9));
        for step in 1..=3u64 {
            let rep = c.save(step, &[data(0, 40_000, step)]).unwrap();
            assert!(rep.device_resident, "step {step} admitted to HBM");
            assert!(rep.d2h_s > 0.0, "D2H drain modeled");
        }
        c.flush().unwrap();
        // Newest two pinned; step 1 trimmed out of the window.
        assert_eq!(c.device_steps(), vec![2, 3]);
        assert!(!c.device_resident(1));
        // A pinned step restores straight from HBM.
        let (back, tier) = c.restore(3).unwrap();
        assert_eq!(tier, Tier::Device);
        assert_eq!(back[0].tensors, data(0, 40_000, 3).tensors);
        // An unpinned step falls through to storage.
        let (_, tier1) = c.restore(1).unwrap();
        assert_eq!(tier1, Tier::Storage(0));
        // restore_latest sees the device-resident newest step.
        let (step, _, tier) = c.restore_latest().unwrap();
        assert_eq!((step, tier), (3, Tier::Device));
        std::fs::remove_dir_all(&base).unwrap();
    }

    #[test]
    fn replica_outranks_pfs_and_replicates_off_critical_path() {
        use crate::coordinator::Topology;
        use crate::tier::replica::{PlacementPolicy, ReplicaTier};
        let (c, base) = two_tier("rep", TierPolicy::WriteBack { drain_depth: 2 });
        let rt = ReplicaTier::new(
            base.join("peers"),
            Topology::polaris(8), // 2 nodes: node 0's buddy is node 1
            0,
            PlacementPolicy::BuddyRing,
            1,
        )
        .unwrap();
        let c = c.with_replica_tier(rt);
        let input = vec![data(0, 60_000, 21)];
        c.save(21, &input).unwrap();
        c.flush().unwrap();
        assert_eq!(c.replication_lag(), 0);
        assert!(c.replica_committed_at(21));
        // The burst buffer serves first…
        let (_, tier) = c.restore(21).unwrap();
        assert_eq!(tier, Tier::Storage(0));
        // …after the bb copy goes, the buddy replica outranks the PFS…
        c.evict(0, 21).unwrap();
        let (back, tier) = c.restore(21).unwrap();
        assert_eq!(tier, Tier::Replica(1));
        assert_eq!(back[0].tensors, input[0].tensors);
        // …and restore_latest counts replica-held steps.
        let (step, _, _) = c.restore_latest().unwrap();
        assert_eq!(step, 21);
        std::fs::remove_dir_all(&base).unwrap();
    }

    #[test]
    fn corrupt_replica_falls_through_to_pfs() {
        use crate::coordinator::Topology;
        use crate::tier::replica::{PlacementPolicy, ReplicaTier};
        let (c, base) = two_tier("repcorrupt", TierPolicy::WriteBack { drain_depth: 1 });
        let rt = ReplicaTier::new(
            base.join("peers"),
            Topology::polaris(8),
            0,
            PlacementPolicy::BuddyRing,
            1,
        )
        .unwrap();
        let c = c.with_replica_tier(rt);
        let input = vec![data(0, 50_000, 33)];
        c.save(33, &input).unwrap();
        c.flush().unwrap();
        c.evict(0, 33).unwrap();
        // Flip a byte in the replica's data: verification must reject
        // it and the restore must fall through to the PFS copy.
        let rt = c.replica_tier().unwrap();
        let rep_dir = rt.store_dir(0, 1, 33);
        let victim = std::fs::read_dir(&rep_dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .find(|p| {
                p.is_file()
                    && p.file_name()
                        .is_some_and(|n| n.to_string_lossy().ends_with(".bin"))
            })
            .expect("replica data file");
        let mut bytes = std::fs::read(&victim).unwrap();
        bytes[64] ^= 0xFF;
        std::fs::write(&victim, bytes).unwrap();
        let (back, tier) = c.restore(33).unwrap();
        assert_eq!(tier, Tier::Storage(1), "fell through to the PFS");
        assert_eq!(back[0].tensors, input[0].tensors);
        std::fs::remove_dir_all(&base).unwrap();
    }

    #[test]
    fn registry_mirrors_resident_sets() {
        let (c, base) = two_tier("reg", TierPolicy::WriteBack { drain_depth: 2 });
        c.save(1, &[data(0, 9_000, 1)]).unwrap();
        c.save(2, &[data(0, 9_000, 2)]).unwrap();
        c.flush().unwrap();
        {
            let reg = c.registry().lock();
            for tier in 0..2 {
                assert_eq!(
                    reg.storage_steps(tier),
                    c.resident_steps(tier),
                    "tier {tier}"
                );
            }
        }
        c.evict(0, 1).unwrap();
        assert!(!c.registry().lock().durable_at(0, 1));
        assert!(c.registry().lock().durable_at(1, 1));
        std::fs::remove_dir_all(&base).unwrap();
    }

    #[test]
    fn replica_prefetch_pulls_buddy_copy_into_burst_buffer() {
        use crate::coordinator::Topology;
        use crate::tier::replica::{PlacementPolicy, ReplicaTier};
        let (c, base) = two_tier("repfetch", TierPolicy::LocalOnlyEveryK { k: 100 });
        let mk_rt = || {
            ReplicaTier::new(
                base.join("peers"),
                Topology::polaris(8),
                0,
                PlacementPolicy::BuddyRing,
                1,
            )
            .unwrap()
        };
        let c = c.with_replica_tier(mk_rt());
        let input = vec![data(0, 30_000, 44)];
        c.save(44, &input).unwrap();
        c.flush().unwrap();
        drop(c);
        // The node is replaced: its burst buffer is gone; only the
        // buddy replica survives (k=100 kept the PFS out of it).
        std::fs::remove_dir_all(base.join("bb")).unwrap();
        let tiers = vec![
            TierSpec::new("bb", base.join("bb")).with_backend(BackendKind::Posix),
            TierSpec::new("pfs", base.join("pfs")).with_backend(BackendKind::Posix),
        ];
        let c2 = TierCascade::new(tiers, TierPolicy::LocalOnlyEveryK { k: 100 })
            .unwrap()
            .with_replica_tier(mk_rt());
        let (back, tier) = c2.restore(44).unwrap();
        assert_eq!(tier, Tier::Replica(1));
        assert_eq!(back[0].tensors, input[0].tensors);
        // Replica-aware prefetch: pull the buddy copy back into the
        // rebuilt node's burst buffer on the background workers.
        c2.prefetch(44).unwrap();
        c2.flush().unwrap();
        assert!(c2.committed_at(0, 44), "buddy copy pulled into the bb");
        assert!(c2
            .events()
            .iter()
            .any(|e| matches!(e, TierEvent::Prefetched { tier: 0, step: 44 })));
        let (back2, tier2) = c2.restore(44).unwrap();
        assert_eq!(tier2, Tier::Storage(0), "next restore hits tier 0");
        assert_eq!(back2[0].tensors, input[0].tensors);
        // The rebuilt copy is a primary again, not a replica.
        let m = TierManifest::load(&base.join("bb").join(step_dirname(44))).unwrap();
        assert_eq!(m.replica_of, None);
        std::fs::remove_dir_all(&base).unwrap();
    }

    #[test]
    fn erasure_stripe_survives_two_holder_losses_through_the_cascade() {
        use crate::coordinator::Topology;
        use crate::tier::erasure::{ErasureParams, ErasureTier};
        // k=100 keeps the PFS out of it: after the bb copy goes, only
        // the stripe survives.
        let (c, base) = two_tier("ec", TierPolicy::LocalOnlyEveryK { k: 100 });
        let et = ErasureTier::new(
            base.join("strips"),
            Topology::polaris(28), // 7 single-node failure domains
            0,
            ErasureParams::default(), // RS(4, 2)
        )
        .unwrap();
        let c = c.with_erasure(et);
        let input = vec![data(0, 60_000, 55)];
        c.save(55, &input).unwrap();
        c.flush().unwrap();
        assert!(c.erasure_recoverable_at(55));
        assert_eq!(c.erasure_tier().unwrap().strip_count(55), 6);
        // The burst buffer serves first…
        let (_, tier) = c.restore(55).unwrap();
        assert_eq!(tier, Tier::Storage(0));
        // …and the reconstructible stripe licenses evicting the bb
        // copy even with no PFS copy and nothing newer.
        c.evict(0, 55).unwrap();
        assert!(!c.committed_at(0, 55));
        // Kill two strip holders — one data, one parity: the stripe
        // still reconstructs bit-identically, degraded.
        let et = c.erasure_tier().unwrap();
        let holders = et.holders().to_vec();
        et.fail_node(holders[0]).unwrap();
        et.fail_node(holders[5]).unwrap();
        let (back, tier) = c.restore(55).unwrap();
        assert_eq!(tier, Tier::Erasure);
        assert_eq!(back[0].tensors, input[0].tensors);
        assert_eq!(et.degraded_restore_count(), 1);
        // restore_latest counts stripe-held steps.
        let (step, _, tier) = c.restore_latest().unwrap();
        assert_eq!((step, tier), (55, Tier::Erasure));
        // A third loss drops below k: the restore fails loudly. (The
        // cached materialization from the restore above is a real
        // local copy and would still serve — wipe it to model losing
        // this node too.)
        et.fail_node(holders[1]).unwrap();
        std::fs::remove_dir_all(base.join("strips").join("reconstructed")).unwrap();
        assert!(!c.erasure_recoverable_at(55));
        let err = et.restore(55).unwrap_err();
        assert!(err.to_string().contains("only 3 survive"), "{err}");
        assert!(c.restore(55).is_err());
        std::fs::remove_dir_all(&base).unwrap();
    }

    #[test]
    fn restore_elastic_reshards_from_any_tier() {
        use crate::reshard::elastic::{assemble_logical, shard_data};
        use crate::reshard::ReadPlanner;
        use crate::workload::Parallelism;
        let (c, base) = two_tier("elastic", TierPolicy::WriteBack { drain_depth: 2 });
        let mut rng = Xoshiro256::seeded(77);
        let logical: Vec<(String, Vec<u8>)> = (0..6)
            .map(|i| {
                let mut b = vec![0u8; 4 * 3000 + 4 * i];
                rng.fill_bytes(&mut b);
                let name = if i % 2 == 0 {
                    format!("layers.{i}.w")
                } else {
                    format!("optim.s{i}")
                };
                (name, b)
            })
            .collect();
        let src = Parallelism::new(2, 1, 2);
        let data = shard_data(&logical, src, &lean::training_state(7, 1e-3, "el"));
        c.save(7, &data).unwrap();
        c.flush().unwrap();
        let planner = ReadPlanner::default().with_gap_fill(64 * 1024);
        let dst = Parallelism::new(1, 2, 1);
        // Served from the burst buffer first.
        let (d0, tier0) = c.restore_elastic(7, dst, &planner).unwrap();
        assert_eq!(tier0, Tier::Storage(0));
        assert_eq!(d0.len(), dst.world());
        let sorted = |mut v: Vec<(String, Vec<u8>)>| {
            v.sort_by(|a, b| a.0.cmp(&b.0));
            v
        };
        assert_eq!(sorted(assemble_logical(&d0).unwrap()), sorted(logical.clone()));
        // Evict the bb copy: the PFS serves the same resharded bytes.
        c.evict(0, 7).unwrap();
        let (d1, tier1) = c.restore_elastic(7, dst, &planner).unwrap();
        assert_eq!(tier1, Tier::Storage(1));
        assert_eq!(sorted(assemble_logical(&d1).unwrap()), sorted(logical.clone()));
        std::fs::remove_dir_all(&base).unwrap();
    }

    #[test]
    fn recovery_rescans_committed_dirs() {
        let (c, base) = two_tier("recover", TierPolicy::WriteBack { drain_depth: 1 });
        c.save(7, &[data(0, 12_000, 7)]).unwrap();
        c.flush().unwrap();
        drop(c);
        // A fresh cascade over the same roots sees the checkpoint.
        let tiers = vec![
            TierSpec::new("bb", base.join("bb")).with_backend(BackendKind::Posix),
            TierSpec::new("pfs", base.join("pfs")).with_backend(BackendKind::Posix),
        ];
        let c2 = TierCascade::new(tiers, TierPolicy::WriteBack { drain_depth: 1 }).unwrap();
        assert!(c2.committed_at(0, 7) && c2.committed_at(1, 7));
        let (step, _, _) = c2.restore_latest().unwrap();
        assert_eq!(step, 7);
        std::fs::remove_dir_all(&base).unwrap();
    }
}
