//! Restore-side prefetch: overlap PFS→burst-buffer pulls with shard
//! loading.
//!
//! Restoring a training job replays a *sequence* of reads (the target
//! checkpoint, and in speculative-rollback workflows several candidate
//! checkpoints). While the current checkpoint's shards load from the
//! burst buffer, the next one's files can already be in flight from the
//! PFS — the same overlap trick as write-back, pointed the other way.

use std::collections::VecDeque;

use crate::ckpt::store::RankData;
use crate::error::Result;

use super::cascade::TierCascade;
use super::Tier;

/// Walks a schedule of checkpoint steps, prefetching each step's
/// successor into the burst buffer before serving the current restore.
pub struct RestorePrefetcher<'a> {
    cascade: &'a TierCascade,
    schedule: VecDeque<u64>,
}

impl<'a> RestorePrefetcher<'a> {
    pub fn new(cascade: &'a TierCascade, steps: impl IntoIterator<Item = u64>) -> Self {
        Self {
            cascade,
            schedule: steps.into_iter().collect(),
        }
    }

    /// Steps still scheduled.
    pub fn remaining(&self) -> usize {
        self.schedule.len()
    }

    /// Restore the next scheduled step, kicking off the prefetch of the
    /// one after it first so the pull overlaps this load. Returns
    /// `None` when the schedule is exhausted.
    #[allow(clippy::should_implement_trait)]
    pub fn next(&mut self) -> Option<Result<(u64, Vec<RankData>, Tier)>> {
        let step = self.schedule.pop_front()?;
        if let Some(&upcoming) = self.schedule.front() {
            // Best-effort: a failed prefetch only costs the overlap.
            let _ = self.cascade.prefetch(upcoming);
        }
        Some(self.cascade.restore(step).map(|(data, tier)| (step, data, tier)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ckpt::lean;
    use crate::exec::real::BackendKind;
    use crate::tier::{TierPolicy, TierSpec};
    use crate::util::prng::Xoshiro256;

    fn data(step: u64) -> Vec<RankData> {
        let mut rng = Xoshiro256::seeded(step);
        let mut b = vec![0u8; 20_000];
        rng.fill_bytes(&mut b);
        vec![RankData {
            rank: 0,
            tensors: vec![("w".into(), b)],
            lean: lean::training_state(step, 1e-3, "pf"),
        }]
    }

    #[test]
    fn prefetch_schedule_restores_in_order_and_repopulates_bb() {
        let base = std::env::temp_dir().join(format!("ckptio-pf-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&base);
        let tiers = vec![
            TierSpec::new("bb", base.join("bb")).with_backend(BackendKind::Posix),
            TierSpec::new("pfs", base.join("pfs")).with_backend(BackendKind::Posix),
        ];
        let c = TierCascade::new(tiers, TierPolicy::WriteBack { drain_depth: 2 }).unwrap();
        for step in [1u64, 2, 3] {
            c.save(step, &data(step)).unwrap();
        }
        c.flush().unwrap();
        // Simulate a burst-buffer wipe: everything must come from PFS,
        // except what the prefetcher pulls back in.
        for step in [1u64, 2, 3] {
            c.evict(0, step).unwrap();
        }

        let mut pf = RestorePrefetcher::new(&c, [1u64, 2, 3]);
        let (s1, d1, t1) = pf.next().unwrap().unwrap();
        assert_eq!((s1, t1), (1, Tier::Storage(1)), "first restore comes from PFS");
        assert_eq!(d1[0].tensors, data(1)[0].tensors);
        // Let the async prefetch of step 2 settle, then restore it.
        c.flush().unwrap();
        let (s2, d2, t2) = pf.next().unwrap().unwrap();
        assert_eq!(
            (s2, t2),
            (2, Tier::Storage(0)),
            "second restore hits the burst buffer"
        );
        assert_eq!(d2[0].tensors, data(2)[0].tensors);
        c.flush().unwrap();
        let (s3, _, t3) = pf.next().unwrap().unwrap();
        assert_eq!((s3, t3), (3, Tier::Storage(0)));
        assert!(pf.next().is_none());
        std::fs::remove_dir_all(&base).unwrap();
    }
}
