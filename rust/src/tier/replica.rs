//! `ReplicaTier` — the inter-node peer replica layer between the burst
//! buffer and the PFS.
//!
//! TierCheck's observation: a node's burst-buffer checkpoint dies with
//! the node, and restoring from the PFS pays the slowest tier's
//! latency. Replicating each rank group's burst-buffer shards into a
//! *buddy* node's DRAM/SSD tolerates single-node loss while restoring
//! at fabric speed — and, per DataStates-LLM, the replication must be
//! asynchronous so it never stalls the training step.
//!
//! This module provides:
//!
//! * [`PlacementPolicy`] — who the buddies are, computed over
//!   [`Topology`]: a buddy ring (next nodes on the ring, skipping the
//!   source's failure domain) or a failure-domain-aware spread (one
//!   buddy per *distinct* foreign domain). Both uphold the invariant
//!   that **a replica never lands on the source node or in the
//!   source's failure domain** (`tests/prop_invariants.rs` pins this
//!   down for arbitrary topologies and fan-outs).
//! * [`ReplicaTier`] — the real-storage replica store: per-buddy
//!   directories (`node{j}/from_node{i}/step_*`), crash-consistent
//!   commits through [`TierManifest`] (data copied and fsynced strictly
//!   before the manifest's temp+rename, with `replica_of` recording the
//!   owner), per-buddy capacity budgets whose eviction only ever takes
//!   victims that are strictly older *and* durable on the PFS — so a
//!   replica eviction can never drop the last surviving copy of a step.
//! * [`replica_drain_plan`] — the plan transform that expresses the
//!   replication pump on the simulator: reads from the burst buffer,
//!   writes to `peer/n{buddy}/…` paths, which
//!   [`crate::simpfs::exec::SimExecutor`] routes over the per-node
//!   peer-fabric lane (`net_peer_*` [`crate::simpfs::SimParams`])
//!   *and* the node's NIC egress port, so replication contends with
//!   PFS flushes exactly where the hardware makes them contend. Run it
//!   via `SimExecutor::with_background_drains` to model the pump as a
//!   native low-priority rank.
//!
//! [`crate::tier::TierCascade::with_replica_tier`] attaches a
//! `ReplicaTier` between storage tier 0 and the slower tiers: saves
//! enqueue asynchronous replication on the cascade's worker pool, and
//! a restore falls back burst buffer → peer replica → PFS, fastest
//! surviving copy first.

use std::collections::{BTreeMap, BTreeSet};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use crate::ckpt::store::{CheckpointStore, RankData};
use crate::coordinator::topology::Topology;
use crate::error::{Error, Result};
use crate::exec::real::BackendKind;
use crate::plan::RankPlan;

use super::cascade::{parse_step_dirname, step_dirname};
use super::manifest::TierManifest;
use super::registry::{Copies, CopiesRegistry};
use super::{model, writeback, PEER_TIER_PREFIX};

/// Build the simulator path addressing `dst_node`'s replica store.
pub fn peer_path(dst_node: usize, path: &str) -> String {
    format!("{PEER_TIER_PREFIX}n{dst_node}/{path}")
}

/// Parse the destination node out of a peer-store path
/// (`peer/n{dst}/…`); `None` for non-peer paths.
pub fn parse_peer_node(path: &str) -> Option<usize> {
    path.strip_prefix(PEER_TIER_PREFIX)?
        .split('/')
        .next()?
        .strip_prefix('n')?
        .parse()
        .ok()
}

/// Transform a burst-buffer-targeted checkpoint plan into its
/// replication plan toward `buddy`: read each written extent back from
/// the local tier and push it to the same path under `buddy`'s peer
/// store. Pair with [`crate::tier::model::writeback_drain_plan`] under
/// [`crate::simpfs::exec::SimExecutor::with_background_drains`] to
/// model PFS flush and peer replication contending for NIC egress.
pub fn replica_drain_plan(plan: &RankPlan, buddy: usize) -> RankPlan {
    model::drain_plan_with(plan, |stripped| peer_path(buddy, stripped))
}

/// How a node's replicas are placed on its peers. Both policies
/// guarantee a replica never lands on the source node or in the
/// source's failure domain ([`Topology::domain_of`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlacementPolicy {
    /// The next `fan_out` nodes along the node ring, skipping any node
    /// that shares the source's failure domain. Cheapest bookkeeping;
    /// with racks larger than one node, consecutive sources may map
    /// into the same foreign rack.
    BuddyRing,
    /// One buddy per *distinct* foreign failure domain, walking domains
    /// round-robin from the source's; within each domain the buddy is
    /// picked by the source's own within-domain index, spreading
    /// replica ingest load across the rack instead of hammering its
    /// first node. Tolerates `fan_out` simultaneous whole-domain
    /// failures (plus the source's own).
    FailureDomainAware,
}

impl PlacementPolicy {
    /// The buddy nodes `node` replicates to, in preference order.
    /// Errors when the topology cannot host the fan-out outside the
    /// source's failure domain (a replica co-located with its source
    /// would be lost with it — never silently degrade).
    pub fn buddies_of(&self, topo: &Topology, node: usize, fan_out: usize) -> Result<Vec<usize>> {
        let n = topo.n_nodes();
        if node >= n {
            return Err(Error::config(format!(
                "placement: node {node} outside topology of {n} nodes"
            )));
        }
        if fan_out == 0 {
            return Err(Error::config("placement: fan_out must be >= 1"));
        }
        let dom = topo.domain_of(node);
        match self {
            PlacementPolicy::BuddyRing => {
                let out: Vec<usize> = (1..n)
                    .map(|i| (node + i) % n)
                    .filter(|&c| topo.domain_of(c) != dom)
                    .take(fan_out)
                    .collect();
                if out.len() < fan_out {
                    return Err(Error::config(format!(
                        "placement: only {} nodes outside node {node}'s failure domain; \
                         cannot host fan-out {fan_out}",
                        out.len()
                    )));
                }
                Ok(out)
            }
            PlacementPolicy::FailureDomainAware => {
                let nd = topo.n_domains();
                let within = node - topo.nodes_in(dom).start;
                let mut out = Vec::with_capacity(fan_out);
                for i in 1..nd {
                    let d = (dom + i) % nd;
                    let nodes: Vec<usize> = topo.nodes_in(d).collect();
                    if nodes.is_empty() {
                        continue;
                    }
                    out.push(nodes[within % nodes.len()]);
                    if out.len() == fan_out {
                        break;
                    }
                }
                if out.len() < fan_out {
                    return Err(Error::config(format!(
                        "placement: {nd} failure domains cannot host fan-out {fan_out} \
                         outside node {node}'s domain"
                    )));
                }
                Ok(out)
            }
        }
    }
}

/// Observable replica-store transitions, in occurrence order. The
/// invariant mirroring the cascade's: a `Committed { buddy, step }`
/// is always preceded by its `DataSynced { buddy, step }`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReplicaEvent {
    /// All of `step`'s data blocks landed (written + fsynced) in
    /// `buddy`'s store.
    DataSynced { buddy: usize, step: u64 },
    /// `step`'s replica manifest committed at `buddy` (ack: the copy
    /// now counts as durable for eviction decisions).
    Committed { buddy: usize, step: u64 },
    /// `step`'s replica at `buddy` was evicted (capacity).
    Evicted { buddy: usize, step: u64 },
}

/// Outcome of replicating one step.
#[derive(Debug, Clone)]
pub struct ReplicaReport {
    pub step: u64,
    pub payload_bytes: u64,
    /// Buddies whose copy committed (acked).
    pub acked: Vec<usize>,
    /// Per-buddy failures (capacity, I/O); empty on full success.
    pub errors: Vec<String>,
}

#[derive(Default)]
struct ReplicaState {
    /// step → buddy nodes holding a committed (acked) replica.
    committed: BTreeMap<u64, BTreeSet<usize>>,
    /// (buddy, step) → committed payload bytes there.
    sizes: BTreeMap<(usize, u64), u64>,
    /// Per-buddy committed bytes (capacity accounting).
    used: BTreeMap<usize, u64>,
    /// Steps queued or mid-replication (not yet acked anywhere).
    pending: BTreeSet<u64>,
    /// Steps whose last replication attempt failed on *every* buddy —
    /// saved locally but carrying no off-node copy. Counted into the
    /// replication lag so "lag == 0" really means "protected"; cleared
    /// by a later successful re-replication.
    failed: BTreeSet<u64>,
    events: Vec<ReplicaEvent>,
    /// Lifetime capacity evictions (one per [`ReplicaEvent::Evicted`]).
    evictions: u64,
    /// Re-saves of a step that was still queued or mid-replication when
    /// [`ReplicaTier::mark_pending`] was called for it again.
    resave_races: u64,
}

/// The inter-node replica store (see the module docs).
///
/// On real storage, peer nodes are directories under one root:
/// `root/node{j}/from_node{i}/step_NNNNNNNN/` holds node `i`'s
/// replicated checkpoint in node `j`'s store. The same layout serves a
/// replacement node restoring a dead node's shards
/// ([`ReplicaTier::restore_node`]).
pub struct ReplicaTier {
    topo: Topology,
    policy: PlacementPolicy,
    fan_out: usize,
    node: usize,
    buddies: Vec<usize>,
    root: PathBuf,
    capacity_per_node: u64,
    backend: BackendKind,
    queue_depth: u32,
    state: Mutex<ReplicaState>,
    /// Shared copies registry (attached by
    /// [`crate::tier::TierCascade::with_replica_tier`]): when present,
    /// budget-eviction decisions read "durable on the slowest tier"
    /// out of it *under its lock*, serializing against the cascade's
    /// concurrent evictions. Without one, the caller-supplied
    /// `durable_elsewhere` snapshot gates eviction as before.
    registry: Option<Arc<CopiesRegistry>>,
}

impl ReplicaTier {
    /// A replica tier for `node`'s rank group, replicating into the
    /// `fan_out` buddies `policy` selects over `topo`. Existing
    /// committed replica directories under `root` (from `node`) are
    /// recovered into the accounting — the crash-restart path. Errors
    /// when the topology cannot host the placement.
    pub fn new(
        root: impl Into<PathBuf>,
        topo: Topology,
        node: usize,
        policy: PlacementPolicy,
        fan_out: usize,
    ) -> Result<Self> {
        let buddies = policy.buddies_of(&topo, node, fan_out)?;
        let root = root.into();
        std::fs::create_dir_all(&root)?;
        let mut state = ReplicaState::default();
        for &buddy in &buddies {
            let dir = root.join(format!("node{buddy}")).join(format!("from_node{node}"));
            let entries = match std::fs::read_dir(&dir) {
                Ok(e) => e,
                Err(_) => continue, // nothing replicated there yet
            };
            for entry in entries {
                let entry = entry?;
                let p = entry.path();
                if !p.is_dir() {
                    continue;
                }
                let name = entry.file_name().to_string_lossy().into_owned();
                if let Some(step) = parse_step_dirname(&name) {
                    // Only committed replicas count; uncommitted crash
                    // remains are invisible (clobbered on re-replication).
                    if let Ok(m) = TierManifest::load(&p) {
                        if m.step == step {
                            let bytes = m.payload_bytes();
                            state.committed.entry(step).or_default().insert(buddy);
                            state.sizes.insert((buddy, step), bytes);
                            *state.used.entry(buddy).or_insert(0) += bytes;
                        }
                    }
                }
            }
        }
        Ok(Self {
            topo,
            policy,
            fan_out,
            node,
            buddies,
            root,
            capacity_per_node: u64::MAX,
            backend: BackendKind::Posix,
            queue_depth: 32,
            state: Mutex::new(state),
            registry: None,
        })
    }

    /// Per-buddy replica budget in bytes (`u64::MAX` = unbounded).
    /// Covers this owner's replicas at each buddy.
    pub fn with_capacity_per_node(mut self, bytes: u64) -> Self {
        self.capacity_per_node = bytes.max(1);
        self
    }

    /// Attach the shared copies registry (see the `registry` field) and
    /// seed it with the replicas the recovery scan already found.
    pub fn with_registry(mut self, registry: Arc<CopiesRegistry>) -> Self {
        {
            // Registry strictly before the component lock.
            let mut reg = registry.lock();
            let st = self.state.lock().unwrap();
            for (step, buddies) in &st.committed {
                for &b in buddies {
                    reg.record_replica(b, *step);
                }
            }
        }
        self.registry = Some(registry);
        self
    }

    pub fn with_backend(mut self, backend: BackendKind) -> Self {
        self.backend = backend;
        self
    }

    pub fn with_queue_depth(mut self, qd: u32) -> Self {
        assert!(qd >= 1);
        self.queue_depth = qd;
        self
    }

    /// The node whose shards this tier replicates out.
    pub fn node(&self) -> usize {
        self.node
    }

    pub fn policy(&self) -> PlacementPolicy {
        self.policy
    }

    pub fn fan_out(&self) -> usize {
        self.fan_out
    }

    pub fn capacity_per_node(&self) -> u64 {
        self.capacity_per_node
    }

    /// The buddy nodes, in placement-preference order.
    pub fn buddies(&self) -> &[usize] {
        &self.buddies
    }

    /// `buddy`'s whole replica store directory (all owners).
    pub fn node_dir(&self, buddy: usize) -> PathBuf {
        self.root.join(format!("node{buddy}"))
    }

    /// Where `owner`'s `step` lives in `buddy`'s store.
    pub fn store_dir(&self, owner: usize, buddy: usize, step: u64) -> PathBuf {
        self.node_dir(buddy)
            .join(format!("from_node{owner}"))
            .join(step_dirname(step))
    }

    /// Mark `step` as queued for replication (pre-enqueue, so the lag
    /// accounting and the cascade's eviction guard see it before the
    /// worker picks it up).
    pub fn mark_pending(&self, step: u64) {
        let mut st = self.state.lock().unwrap();
        if !st.pending.insert(step) {
            // The step was already queued/mid-flight: a re-save raced
            // its own earlier replication. Harmless (the later copy
            // clobbers), but worth surfacing in the trace summary.
            st.resave_races += 1;
        }
    }

    /// Steps queued or mid-replication.
    pub fn pending_steps(&self) -> Vec<u64> {
        self.state.lock().unwrap().pending.iter().copied().collect()
    }

    /// Steps with at least one acked replica, ascending.
    pub fn committed_steps(&self) -> Vec<u64> {
        self.state.lock().unwrap().committed.keys().copied().collect()
    }

    /// Does any buddy hold a committed replica of `step`?
    pub fn committed_at(&self, step: u64) -> bool {
        self.state.lock().unwrap().committed.contains_key(&step)
    }

    /// Buddies holding a committed replica of `step`.
    pub fn acked_buddies(&self, step: u64) -> Vec<usize> {
        self.state
            .lock()
            .unwrap()
            .committed
            .get(&step)
            .map(|s| s.iter().copied().collect())
            .unwrap_or_default()
    }

    /// Newest step with an acked replica.
    pub fn latest_step(&self) -> Option<u64> {
        self.state.lock().unwrap().committed.keys().next_back().copied()
    }

    /// Replication lag: steps saved locally but not acked by any buddy
    /// — queued, mid-replication, or failed everywhere — the
    /// durability window a node failure would lose back to. Strictly:
    /// 0 means every step that asked for protection has at least one
    /// acked off-node copy.
    pub fn replication_lag(&self) -> usize {
        let st = self.state.lock().unwrap();
        st.pending.len() + st.failed.len()
    }

    /// Steps whose last replication attempt failed on every buddy.
    pub fn failed_steps(&self) -> Vec<u64> {
        self.state.lock().unwrap().failed.iter().copied().collect()
    }

    /// This owner's committed replica bytes at `buddy`.
    pub fn used_bytes(&self, buddy: usize) -> u64 {
        self.state
            .lock()
            .unwrap()
            .used
            .get(&buddy)
            .copied()
            .unwrap_or(0)
    }

    /// The event log so far.
    pub fn events(&self) -> Vec<ReplicaEvent> {
        self.state.lock().unwrap().events.clone()
    }

    /// Lifetime capacity evictions.
    pub fn eviction_count(&self) -> u64 {
        self.state.lock().unwrap().evictions
    }

    /// Re-saves that raced a still-pending replication of the same step
    /// (see [`ReplicaTier::mark_pending`]).
    pub fn resave_race_count(&self) -> u64 {
        self.state.lock().unwrap().resave_races
    }

    /// Copy `step` (already committed in `src_dir`, described by
    /// `manifest`) into every buddy's store and commit there — data
    /// strictly before manifest, temp+rename, with `replica_of`
    /// recording the owner. `durable_elsewhere` lists the steps durable
    /// on the cascade's slowest tier: capacity eviction only ever takes
    /// victims that are strictly older than `step` *and* in that set,
    /// so a replica eviction can never drop the last surviving copy.
    ///
    /// Per-buddy failures degrade gracefully: the step is acked as long
    /// as at least one buddy committed; an error is returned only when
    /// every buddy failed.
    pub fn replicate(
        &self,
        step: u64,
        src_dir: &Path,
        manifest: &TierManifest,
        durable_elsewhere: &[u64],
    ) -> Result<ReplicaReport> {
        let files: Vec<(String, u64)> = manifest
            .files
            .iter()
            .map(|f| (f.path.clone(), f.len))
            .collect();
        let payload = manifest.payload_bytes();
        let mut acked = Vec::new();
        let mut errors = Vec::new();
        for &buddy in &self.buddies {
            let res = (|| -> Result<()> {
                // Drop any stale incarnation — accounting *and*
                // directory together — before reserving: a failure
                // below then leaves neither phantom byte counts nor
                // stale data that a restore could serve as this step.
                {
                    let mut reg = self.registry.as_ref().map(|r| r.lock());
                    let mut st = self.state.lock().unwrap();
                    if let Some(old) = st.sizes.remove(&(buddy, step)) {
                        if let Some(u) = st.used.get_mut(&buddy) {
                            *u = u.saturating_sub(old);
                        }
                        let emptied = st
                            .committed
                            .get_mut(&step)
                            .map(|s| {
                                s.remove(&buddy);
                                s.is_empty()
                            })
                            .unwrap_or(false);
                        if emptied {
                            st.committed.remove(&step);
                        }
                        if let Some(reg) = reg.as_mut() {
                            reg.drop_replica(buddy, step);
                        }
                    }
                }
                let dst = self.store_dir(self.node, buddy, step);
                let _ = std::fs::remove_dir_all(&dst); // stale/crash remains
                // Reserve the bytes against the buddy's budget before
                // moving data: the capacity check and the usage charge
                // happen under one lock acquisition, so two concurrent
                // replications (the cascade pool runs several workers)
                // cannot both pass the check and overshoot the budget.
                self.reserve_room(buddy, step, payload, durable_elsewhere)?;
                let copied = (|| -> Result<()> {
                    std::fs::create_dir_all(&dst)?;
                    writeback::copy_files(
                        &files,
                        src_dir,
                        &dst,
                        self.backend,
                        self.backend,
                        self.queue_depth,
                    )?;
                    self.state
                        .lock()
                        .unwrap()
                        .events
                        .push(ReplicaEvent::DataSynced { buddy, step });
                    manifest
                        .clone()
                        .with_replica_of(Some(self.node))
                        .commit(&dst)?;
                    Ok(())
                })();
                let mut reg = self.registry.as_ref().map(|r| r.lock());
                let mut st = self.state.lock().unwrap();
                match copied {
                    Ok(()) => {
                        st.events.push(ReplicaEvent::Committed { buddy, step });
                        st.committed.entry(step).or_default().insert(buddy);
                        // `used` already carries the reservation.
                        st.sizes.insert((buddy, step), payload);
                        if let Some(reg) = reg.as_mut() {
                            reg.record_replica(buddy, step);
                        }
                        Ok(())
                    }
                    Err(e) => {
                        // Release the reservation of the failed copy.
                        if let Some(u) = st.used.get_mut(&buddy) {
                            *u = u.saturating_sub(payload);
                        }
                        Err(e)
                    }
                }
            })();
            match res {
                Ok(()) => acked.push(buddy),
                Err(e) => errors.push(format!("buddy {buddy}: {e}")),
            }
        }
        {
            let mut st = self.state.lock().unwrap();
            st.pending.remove(&step);
            if acked.is_empty() {
                st.failed.insert(step);
            } else {
                st.failed.remove(&step);
            }
        }
        if acked.is_empty() {
            return Err(Error::msg(format!(
                "step {step}: replication failed on every buddy: {}",
                errors.join("; ")
            )));
        }
        Ok(ReplicaReport {
            step,
            payload_bytes: payload,
            acked,
            errors,
        })
    }

    /// Evict this owner's replicas from `buddy` until `incoming` more
    /// bytes fit its budget, then **reserve** those bytes — the final
    /// capacity check and the usage charge happen under one lock, so
    /// concurrent replications never jointly overshoot the budget.
    /// Victims must be strictly older than the incoming step and
    /// durable on the slowest tier.
    ///
    /// With a [`CopiesRegistry`] attached, the whole loop — durable
    /// check, victim selection, and eviction — runs under the registry
    /// lock, so a concurrent cascade PFS-eviction cannot invalidate
    /// the durable read between decision and removal (the single-lock
    /// protocol). Without one, the caller's `durable_elsewhere`
    /// snapshot gates eviction. The caller releases the reservation if
    /// the copy fails.
    fn reserve_room(
        &self,
        buddy: usize,
        step: u64,
        incoming: u64,
        durable_elsewhere: &[u64],
    ) -> Result<()> {
        // Store padding + headers + sidecar slack (as the cascade).
        let need = incoming + incoming / 8 + (1 << 20);
        let slowest = self.registry.as_ref().map(|r| r.slowest_tier());
        let mut reg = self.registry.as_ref().map(|r| r.lock());
        // Victim directories renamed aside by `evict`, deleted only
        // after the registry lock drops — the slow recursive delete
        // must not serialize the global eviction lock.
        let mut doomed: Vec<PathBuf> = Vec::new();
        let outcome = loop {
            // None = fits (bytes reserved); Some(None) = no eligible
            // victim; Some(Some(v)) = evict v and retry.
            let decision = {
                let mut st = self.state.lock().unwrap();
                let used = st.used.get(&buddy).copied().unwrap_or(0);
                if self.capacity_per_node == u64::MAX
                    || used.saturating_add(need) <= self.capacity_per_node
                {
                    *st.used.entry(buddy).or_insert(0) += incoming;
                    None
                } else {
                    Some(
                        st.sizes
                            .keys()
                            .filter(|(b, _)| *b == buddy)
                            .map(|&(_, s)| s)
                            .find(|s| {
                                *s < step
                                    && match (&reg, slowest) {
                                        // A single-tier cascade's
                                        // "slowest tier" is the node's
                                        // own burst buffer, which dies
                                        // with the node — nothing is
                                        // durable through it.
                                        (Some(copies), Some(t)) => {
                                            t > 0 && copies.durable_at(t, *s)
                                        }
                                        _ => durable_elsewhere.contains(s),
                                    }
                            }),
                    )
                }
            };
            match decision {
                None => break Ok(()),
                Some(Some(v)) => match self.evict(buddy, v, reg.as_deref_mut()) {
                    Ok(Some(tmp)) => doomed.push(tmp),
                    Ok(None) => {}
                    Err(e) => break Err(e),
                },
                Some(None) => {
                    break Err(Error::msg(format!(
                        "replica store node{buddy}: {need} bytes will not fit budget {}; \
                         no victim is both older than step {step} and durable on the PFS",
                        self.capacity_per_node
                    )))
                }
            }
        };
        drop(reg);
        for tmp in doomed {
            let _ = std::fs::remove_dir_all(&tmp);
        }
        outcome
    }

    /// Drop this owner's replica of `step` at `buddy`. `reg` is the
    /// already-held registry guard when the caller runs under the
    /// single-lock eviction protocol. The victim directory is renamed
    /// aside (atomic, invisible to manifest loads and recovery scans)
    /// and returned for the caller to delete once the registry lock is
    /// released.
    fn evict(&self, buddy: usize, step: u64, reg: Option<&mut Copies>) -> Result<Option<PathBuf>> {
        let dir = self.store_dir(self.node, buddy, step);
        let doomed = if dir.exists() {
            let tmp = dir.with_extension("evicting");
            let _ = std::fs::remove_dir_all(&tmp); // stale remains
            std::fs::rename(&dir, &tmp)?;
            Some(tmp)
        } else {
            None
        };
        let mut st = self.state.lock().unwrap();
        if let Some(old) = st.sizes.remove(&(buddy, step)) {
            if let Some(u) = st.used.get_mut(&buddy) {
                *u = u.saturating_sub(old);
            }
        }
        let emptied = st
            .committed
            .get_mut(&step)
            .map(|s| {
                s.remove(&buddy);
                s.is_empty()
            })
            .unwrap_or(false);
        if emptied {
            st.committed.remove(&step);
        }
        st.events.push(ReplicaEvent::Evicted { buddy, step });
        st.evictions += 1;
        if let Some(reg) = reg {
            reg.drop_replica(buddy, step);
        }
        Ok(doomed)
    }

    /// Restore this node's `step` from the first buddy holding a
    /// verifying replica (corrupt or truncated copies are skipped, as
    /// in the cascade's tier walk). Returns the data and the serving
    /// buddy.
    pub fn restore(&self, step: u64) -> Result<(Vec<RankData>, usize)> {
        self.restore_node(self.node, step)
    }

    /// Restore `owner`'s `step` — the lost-node path: a replacement
    /// node pulls a dead node's shards out of *its* buddies' stores
    /// (recomputed from the placement policy, so any surviving peer can
    /// run the recovery without the dead node's state).
    pub fn restore_node(&self, owner: usize, step: u64) -> Result<(Vec<RankData>, usize)> {
        let buddies = if owner == self.node {
            self.buddies.clone()
        } else {
            self.policy.buddies_of(&self.topo, owner, self.fan_out)?
        };
        let mut last_err: Option<Error> = None;
        for &buddy in &buddies {
            let dir = self.store_dir(owner, buddy, step);
            let m = match TierManifest::load(&dir) {
                Ok(m) if m.step == step => m,
                _ => continue,
            };
            if let Err(e) = m.verify(&dir) {
                last_err = Some(e);
                continue;
            }
            match CheckpointStore::new(&dir).with_backend(self.backend).load() {
                Ok(data) => return Ok((data, buddy)),
                Err(e) => last_err = Some(e),
            }
        }
        Err(last_err.unwrap_or_else(|| {
            Error::msg(format!(
                "step {step}: no committed replica of node {owner} at any buddy"
            ))
        }))
    }

    /// Simulate losing `node`: its whole replica store vanishes (every
    /// owner's replicas hosted there), and the accounting forgets it.
    /// The node's *own* burst buffer is the cascade's to kill.
    pub fn fail_node(&self, node: usize) -> Result<()> {
        let dir = self.node_dir(node);
        if dir.exists() {
            std::fs::remove_dir_all(&dir)?;
        }
        let mut reg = self.registry.as_ref().map(|r| r.lock());
        let mut st = self.state.lock().unwrap();
        let gone: Vec<(usize, u64)> = st
            .sizes
            .keys()
            .filter(|(b, _)| *b == node)
            .copied()
            .collect();
        for (b, s) in gone {
            st.sizes.remove(&(b, s));
            let emptied = st
                .committed
                .get_mut(&s)
                .map(|set| {
                    set.remove(&b);
                    set.is_empty()
                })
                .unwrap_or(false);
            if emptied {
                st.committed.remove(&s);
            }
            if let Some(reg) = reg.as_mut() {
                reg.drop_replica(b, s);
            }
        }
        st.used.remove(&node);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ckpt::lean;
    use crate::util::prng::Xoshiro256;

    fn tmp(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "ckptio-replica-{name}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn data(rank: usize, bytes: usize, seed: u64) -> RankData {
        let mut rng = Xoshiro256::seeded(seed);
        let mut b = vec![0u8; bytes];
        rng.fill_bytes(&mut b);
        RankData {
            rank,
            tensors: vec![(format!("t{rank}"), b)],
            lean: lean::training_state(seed, 1e-3, "replica"),
        }
    }

    /// Write a committed source checkpoint dir; returns its manifest.
    fn source_step(dir: &Path, step: u64, bytes: usize) -> TierManifest {
        let _ = std::fs::remove_dir_all(dir);
        CheckpointStore::new(dir).save(&[data(0, bytes, step)]).unwrap();
        let m = TierManifest::from_dir(step, dir).unwrap();
        m.commit(dir).unwrap();
        m
    }

    #[test]
    fn peer_path_roundtrip() {
        let p = peer_path(3, "bb/step_00000001/rank000.bin");
        assert!(p.starts_with(PEER_TIER_PREFIX));
        assert_eq!(parse_peer_node(&p), Some(3));
        assert_eq!(parse_peer_node("bb/x"), None);
        assert_eq!(parse_peer_node("peer/x/y"), None);
        assert_eq!(parse_peer_node("peer/n12/y"), Some(12));
    }

    #[test]
    fn buddy_ring_skips_source_and_wraps() {
        let topo = Topology::polaris(16); // 4 nodes, 1-node domains
        let p = PlacementPolicy::BuddyRing;
        assert_eq!(p.buddies_of(&topo, 0, 1).unwrap(), vec![1]);
        assert_eq!(p.buddies_of(&topo, 3, 2).unwrap(), vec![0, 1]);
        // fan-out exhausting the ring errs.
        assert!(p.buddies_of(&topo, 0, 4).is_err());
        // A single-node "cluster" has no buddy.
        assert!(p.buddies_of(&Topology::polaris(4), 0, 1).is_err());
    }

    #[test]
    fn buddy_ring_skips_whole_source_domain() {
        // 6 nodes in racks of 2: node 2's domain is {2, 3}.
        let topo = Topology::polaris(24).with_nodes_per_domain(2);
        let b = PlacementPolicy::BuddyRing.buddies_of(&topo, 2, 3).unwrap();
        assert_eq!(b, vec![4, 5, 0]);
        assert!(!b.contains(&2) && !b.contains(&3));
    }

    #[test]
    fn failure_domain_policy_spreads_across_distinct_domains() {
        // 6 nodes, racks of 2, 3 domains.
        let topo = Topology::polaris(24).with_nodes_per_domain(2);
        let p = PlacementPolicy::FailureDomainAware;
        // node 0 (domain 0, index 0): first node of domains 1 and 2.
        assert_eq!(p.buddies_of(&topo, 0, 2).unwrap(), vec![2, 4]);
        // node 1 (domain 0, index 1): second node of each foreign rack.
        assert_eq!(p.buddies_of(&topo, 1, 2).unwrap(), vec![3, 5]);
        // Distinct domains cap the fan-out at n_domains - 1.
        assert!(p.buddies_of(&topo, 0, 3).is_err());
        // Domains of the chosen buddies are pairwise distinct and never
        // the source's.
        let b = p.buddies_of(&topo, 3, 2).unwrap();
        let doms: Vec<usize> = b.iter().map(|&n| topo.domain_of(n)).collect();
        assert!(!doms.contains(&topo.domain_of(3)));
        assert_ne!(doms[0], doms[1]);
    }

    #[test]
    fn replicate_restore_roundtrip_with_commit_order() {
        let base = tmp("rt");
        let topo = Topology::polaris(8); // 2 nodes
        let rt = ReplicaTier::new(
            base.join("peers"),
            topo,
            0,
            PlacementPolicy::BuddyRing,
            1,
        )
        .unwrap();
        assert_eq!(rt.buddies(), &[1]);
        let src = base.join("bb").join(step_dirname(5));
        let m = source_step(&src, 5, 60_000);
        rt.mark_pending(5);
        assert_eq!(rt.replication_lag(), 1);
        // A re-save while step 5 is still queued is the race the
        // counter surfaces (lag stays 1 — the set deduplicates).
        rt.mark_pending(5);
        assert_eq!(rt.replication_lag(), 1);
        assert_eq!(rt.resave_race_count(), 1);
        let rep = rt.replicate(5, &src, &m, &[]).unwrap();
        assert_eq!(rep.acked, vec![1]);
        assert!(rep.errors.is_empty());
        assert_eq!(rt.replication_lag(), 0);
        assert!(rt.committed_at(5));
        assert_eq!(rt.latest_step(), Some(5));
        // Data-synced strictly before committed.
        let ev = rt.events();
        let ds = ev
            .iter()
            .position(|e| matches!(e, ReplicaEvent::DataSynced { buddy: 1, step: 5 }))
            .unwrap();
        let cm = ev
            .iter()
            .position(|e| matches!(e, ReplicaEvent::Committed { buddy: 1, step: 5 }))
            .unwrap();
        assert!(ds < cm);
        // Bit-exact restore, and the manifest records the owner.
        let (back, buddy) = rt.restore(5).unwrap();
        assert_eq!(buddy, 1);
        assert_eq!(back[0].tensors, data(0, 60_000, 5).tensors);
        let stored = TierManifest::load(&rt.store_dir(0, 1, 5)).unwrap();
        assert_eq!(stored.replica_of, Some(0));
        std::fs::remove_dir_all(&base).unwrap();
    }

    #[test]
    fn recovery_rescans_committed_replicas() {
        let base = tmp("recover");
        let topo = Topology::polaris(8);
        let mk = || {
            ReplicaTier::new(
                base.join("peers"),
                topo,
                0,
                PlacementPolicy::BuddyRing,
                1,
            )
            .unwrap()
        };
        let rt = mk();
        let src = base.join("bb").join(step_dirname(3));
        let m = source_step(&src, 3, 20_000);
        rt.replicate(3, &src, &m, &[]).unwrap();
        drop(rt);
        let rt2 = mk();
        assert!(rt2.committed_at(3));
        assert!(rt2.used_bytes(1) > 0);
        let (back, _) = rt2.restore(3).unwrap();
        assert_eq!(back[0].tensors, data(0, 20_000, 3).tensors);
        std::fs::remove_dir_all(&base).unwrap();
    }

    #[test]
    fn capacity_evicts_only_older_durable_steps() {
        let base = tmp("cap");
        let topo = Topology::polaris(8);
        // Budget fits roughly one 1 MiB step (plus slack).
        let rt = ReplicaTier::new(
            base.join("peers"),
            topo,
            0,
            PlacementPolicy::BuddyRing,
            1,
        )
        .unwrap()
        .with_capacity_per_node(3 << 20);
        let src1 = base.join("bb").join(step_dirname(1));
        let m1 = source_step(&src1, 1, 1 << 20);
        rt.replicate(1, &src1, &m1, &[]).unwrap();
        // Step 2 does not fit; step 1 is NOT durable elsewhere → the
        // eviction refuses and this buddy's replication fails loudly.
        let src2 = base.join("bb").join(step_dirname(2));
        let m2 = source_step(&src2, 2, 1 << 20);
        let err = rt.replicate(2, &src2, &m2, &[]).unwrap_err();
        assert!(err.to_string().contains("durable"), "{err}");
        assert!(rt.committed_at(1), "step 1's replica survived");
        // With step 1 durable on the PFS, it is evictable and step 2
        // replicates.
        rt.replicate(2, &src2, &m2, &[1]).unwrap();
        assert!(rt.committed_at(2));
        assert!(!rt.committed_at(1), "older durable step evicted");
        let ev = rt.events();
        assert!(ev
            .iter()
            .any(|e| matches!(e, ReplicaEvent::Evicted { buddy: 1, step: 1 })));
        assert_eq!(rt.eviction_count(), 1);
        std::fs::remove_dir_all(&base).unwrap();
    }

    #[test]
    fn registry_gates_eviction_durability_under_one_lock() {
        let base = tmp("reglock");
        let topo = Topology::polaris(8);
        let registry = Arc::new(CopiesRegistry::new(1));
        let rt = ReplicaTier::new(
            base.join("peers"),
            topo,
            0,
            PlacementPolicy::BuddyRing,
            1,
        )
        .unwrap()
        .with_capacity_per_node(3 << 20)
        .with_registry(Arc::clone(&registry));
        let src1 = base.join("bb").join(step_dirname(1));
        let m1 = source_step(&src1, 1, 1 << 20);
        rt.replicate(1, &src1, &m1, &[]).unwrap();
        assert_eq!(registry.lock().replica_steps(), vec![1]);
        // With a registry attached, the legacy durable snapshot is
        // ignored: even claiming step 1 durable via the argument, the
        // registry says it is not on the slowest tier → refuse.
        let src2 = base.join("bb").join(step_dirname(2));
        let m2 = source_step(&src2, 2, 1 << 20);
        let err = rt.replicate(2, &src2, &m2, &[1]).unwrap_err();
        assert!(err.to_string().contains("durable"), "{err}");
        assert!(rt.committed_at(1));
        // Record step 1 on the slowest tier (what the cascade's PFS
        // commit does) → now evictable, and the eviction runs under
        // the same registry lock the durable read took.
        registry.lock().record_storage(1, 1);
        rt.replicate(2, &src2, &m2, &[]).unwrap();
        assert!(rt.committed_at(2));
        assert!(!rt.committed_at(1), "older durable step evicted");
        assert_eq!(registry.lock().replica_steps(), vec![2]);
        std::fs::remove_dir_all(&base).unwrap();
    }

    #[test]
    fn uncommitted_partial_replica_is_invisible() {
        let base = tmp("partial");
        let topo = Topology::polaris(8);
        let rt = ReplicaTier::new(
            base.join("peers"),
            topo,
            0,
            PlacementPolicy::BuddyRing,
            1,
        )
        .unwrap();
        // A crash mid-copy: data bytes present, no manifest.
        let dst = rt.store_dir(0, 1, 4);
        std::fs::create_dir_all(&dst).unwrap();
        std::fs::write(dst.join("rank000.bin"), vec![7u8; 1000]).unwrap();
        assert!(rt.restore(4).is_err());
        assert!(!rt.committed_at(4));
        // And a fresh scan ignores it too.
        drop(rt);
        let rt2 = ReplicaTier::new(
            base.join("peers"),
            topo,
            0,
            PlacementPolicy::BuddyRing,
            1,
        )
        .unwrap();
        assert!(!rt2.committed_at(4));
        std::fs::remove_dir_all(&base).unwrap();
    }

    #[test]
    fn fan_out_two_survives_first_buddy_loss() {
        let base = tmp("fan2");
        let topo = Topology::polaris(12); // 3 nodes
        let rt = ReplicaTier::new(
            base.join("peers"),
            topo,
            0,
            PlacementPolicy::BuddyRing,
            2,
        )
        .unwrap();
        assert_eq!(rt.buddies(), &[1, 2]);
        let src = base.join("bb").join(step_dirname(7));
        let m = source_step(&src, 7, 30_000);
        let rep = rt.replicate(7, &src, &m, &[]).unwrap();
        assert_eq!(rep.acked, vec![1, 2]);
        rt.fail_node(1).unwrap();
        let (back, buddy) = rt.restore(7).unwrap();
        assert_eq!(buddy, 2);
        assert_eq!(back[0].tensors, data(0, 30_000, 7).tensors);
        std::fs::remove_dir_all(&base).unwrap();
    }

    #[test]
    fn replica_drain_plan_targets_peer_store() {
        use crate::plan::{BufSlice, FileSpec, PlanOp};
        let mut p = RankPlan::new(0, 0);
        let f = p.add_file(FileSpec {
            path: format!("{}r0.bin", super::super::LOCAL_TIER_PREFIX),
            direct: true,
            size_hint: 1 << 20,
            creates: true,
        });
        p.push(PlanOp::Create { file: f });
        p.push(PlanOp::Write {
            file: f,
            offset: 0,
            src: BufSlice::new(0, 1 << 20),
        });
        p.push(PlanOp::Drain);
        p.push(PlanOp::Fsync { file: f });
        let d = replica_drain_plan(&p, 2);
        d.validate().unwrap();
        assert_eq!(d.files.len(), 2);
        assert_eq!(d.files[1].path, "peer/n2/r0.bin");
        assert_eq!(parse_peer_node(&d.files[1].path), Some(2));
        assert_eq!(d.read_bytes(), 1 << 20);
        assert_eq!(d.write_bytes(), 1 << 20);
    }
}
