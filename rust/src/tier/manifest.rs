//! Per-tier commit manifests — the cascade's crash-consistency unit.
//!
//! A checkpoint directory at a tier holds data files (whatever layout
//! the engine/store produced) plus, once complete, a `TIER_COMMIT.json`
//! manifest listing every data file with its length and CRC32. The
//! commit protocol is the classic one:
//!
//! 1. data files are written and fsynced;
//! 2. the manifest is written to a temp name and fsynced;
//! 3. the temp file is atomically renamed to [`COMMIT_FILE`].
//!
//! A checkpoint is *durable at a tier* iff its manifest is present and
//! parses; a crash at any earlier point leaves no manifest and the
//! partial directory is garbage-collectable. [`TierManifest::commit`]
//! refuses to run if any listed data block is missing or truncated, so
//! the manifest can never be ordered ahead of its data.

use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::util::json::Json;

/// The atomically-renamed commit marker file name.
pub const COMMIT_FILE: &str = "TIER_COMMIT.json";

/// One data file covered by a commit.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ManifestFile {
    /// Path relative to the checkpoint directory.
    pub path: String,
    pub len: u64,
    pub crc: u32,
}

/// The commit record of one checkpoint at one tier.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TierManifest {
    pub step: u64,
    pub files: Vec<ManifestFile>,
    /// Provenance of the checkpoint's source tier (e.g. `"device"` when
    /// the snapshot was HBM-resident when it entered the cascade).
    /// Optional and ignored by verification — older manifests without
    /// the field load as `None`.
    pub origin: Option<String>,
    /// For a copy living in a peer node's replica store: the node whose
    /// checkpoint shards this directory replicates. Recorded through
    /// the same data-before-manifest temp+rename commit protocol, so a
    /// replica's location is never claimed durably before its bytes
    /// are. `None` for primary (non-replica) copies and for manifests
    /// written before the field existed.
    pub replica_of: Option<usize>,
    /// The coordinator epoch this copy belongs to — the driver's
    /// fencing token against a deposed leader's stale writes. Carried
    /// inside the commit record so a replica's epoch claim rides the
    /// same data-before-manifest temp+rename protocol as its bytes
    /// (no separate marker file that could land without them). `None`
    /// for copies written outside a coordinated run and for manifests
    /// from before the field existed.
    pub epoch: Option<String>,
}

/// fsync a directory so its entries (renames, creates) are durable.
fn sync_dir(dir: &Path) -> Result<()> {
    let d = std::fs::File::open(dir)?;
    d.sync_all()?;
    Ok(())
}

/// Collect all regular files under `dir` (recursive), relative paths,
/// sorted, excluding commit markers and temp files.
fn list_data_files(dir: &Path) -> Result<Vec<PathBuf>> {
    fn walk(dir: &Path, base: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
        for entry in std::fs::read_dir(dir)? {
            let entry = entry?;
            let p = entry.path();
            if p.is_dir() {
                walk(&p, base, out)?;
            } else {
                let rel = p
                    .strip_prefix(base)
                    .map_err(|e| Error::msg(format!("strip_prefix: {e}")))?;
                let name = rel.to_string_lossy().into_owned();
                if name == COMMIT_FILE || name.ends_with(".tmp") {
                    continue;
                }
                out.push(rel.to_path_buf());
            }
        }
        Ok(())
    }
    let mut out = Vec::new();
    walk(dir, dir, &mut out)?;
    out.sort();
    Ok(out)
}

impl TierManifest {
    /// Build a manifest by scanning a checkpoint directory: every data
    /// file is read and CRC'd.
    pub fn from_dir(step: u64, dir: &Path) -> Result<Self> {
        let mut files = Vec::new();
        for rel in list_data_files(dir)? {
            let bytes = std::fs::read(dir.join(&rel))?;
            files.push(ManifestFile {
                path: rel.to_string_lossy().into_owned(),
                len: bytes.len() as u64,
                crc: crc32fast::hash(&bytes),
            });
        }
        if files.is_empty() {
            return Err(Error::Integrity(format!(
                "tier manifest: no data files under {}",
                dir.display()
            )));
        }
        Ok(Self {
            step,
            files,
            origin: None,
            replica_of: None,
            epoch: None,
        })
    }

    /// Record the source-tier provenance (see `origin`).
    pub fn with_origin(mut self, origin: Option<String>) -> Self {
        self.origin = origin;
        self
    }

    /// Mark this manifest as describing a replica of `owner`'s
    /// checkpoint (see `replica_of`).
    pub fn with_replica_of(mut self, owner: Option<usize>) -> Self {
        self.replica_of = owner;
        self
    }

    /// Stamp the coordinator epoch this copy was written under (see
    /// `epoch`).
    pub fn with_epoch(mut self, epoch: Option<String>) -> Self {
        self.epoch = epoch;
        self
    }

    pub fn payload_bytes(&self) -> u64 {
        self.files.iter().map(|f| f.len).sum()
    }

    fn to_json(&self) -> Json {
        let mut doc = Json::obj();
        let mut arr = Vec::with_capacity(self.files.len());
        for f in &self.files {
            let mut o = Json::obj();
            o.set("path", f.path.as_str())
                .set("len", f.len)
                .set("crc", f.crc as u64);
            arr.push(o);
        }
        doc.set("step", self.step)
            .set("payload_bytes", self.payload_bytes())
            .set("files", Json::Arr(arr));
        if let Some(origin) = &self.origin {
            doc.set("origin", origin.as_str());
        }
        if let Some(owner) = self.replica_of {
            doc.set("replica_of", owner as u64);
        }
        if let Some(epoch) = &self.epoch {
            doc.set("epoch", epoch.as_str());
        }
        doc
    }

    fn from_json(doc: &Json) -> Result<Self> {
        let step = doc
            .get("step")
            .and_then(Json::as_u64)
            .ok_or_else(|| Error::format("tier manifest: step"))?;
        let items = doc
            .get("files")
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::format("tier manifest: files"))?;
        let mut files = Vec::with_capacity(items.len());
        for it in items {
            files.push(ManifestFile {
                path: it
                    .get("path")
                    .and_then(Json::as_str)
                    .ok_or_else(|| Error::format("tier manifest: file path"))?
                    .to_string(),
                len: it
                    .get("len")
                    .and_then(Json::as_u64)
                    .ok_or_else(|| Error::format("tier manifest: file len"))?,
                crc: it
                    .get("crc")
                    .and_then(Json::as_u64)
                    .ok_or_else(|| Error::format("tier manifest: file crc"))?
                    as u32,
            });
        }
        let origin = doc
            .get("origin")
            .and_then(Json::as_str)
            .map(str::to_string);
        let replica_of = doc
            .get("replica_of")
            .and_then(Json::as_u64)
            .map(|v| v as usize);
        let epoch = doc
            .get("epoch")
            .and_then(Json::as_str)
            .map(str::to_string);
        Ok(Self {
            step,
            files,
            origin,
            replica_of,
            epoch,
        })
    }

    /// Commit this manifest into `dir`: verify every data block is
    /// present at full length **first**, fsync the directory entries of
    /// the data files, then write-temp + fsync + rename + fsync the
    /// directory again so the rename itself is durable. The ordering
    /// guarantee of the cascade rests here.
    pub fn commit(&self, dir: &Path) -> Result<()> {
        let mut data_dirs = std::collections::BTreeSet::new();
        for f in &self.files {
            let p = dir.join(&f.path);
            let meta = std::fs::metadata(&p).map_err(|e| {
                Error::Integrity(format!(
                    "commit before data: {} missing ({e})",
                    p.display()
                ))
            })?;
            if meta.len() < f.len {
                return Err(Error::Integrity(format!(
                    "commit before data: {} is {} bytes, need {}",
                    p.display(),
                    meta.len(),
                    f.len
                )));
            }
            if let Some(parent) = p.parent() {
                data_dirs.insert(parent.to_path_buf());
            }
        }
        // Data directory entries must be durable before the commit
        // marker can claim the files exist.
        for d in &data_dirs {
            sync_dir(d)?;
        }
        let tmp = dir.join(format!("{COMMIT_FILE}.tmp"));
        std::fs::write(&tmp, self.to_json().to_pretty())?;
        let fh = std::fs::File::open(&tmp)?;
        fh.sync_all()?;
        drop(fh);
        std::fs::rename(&tmp, dir.join(COMMIT_FILE))?;
        // Persist the rename: without this, a power cut can resurrect a
        // directory without the marker (fine) or with a marker whose
        // data entries vanished (prevented by the syncs above).
        sync_dir(dir)?;
        Ok(())
    }

    /// Load the committed manifest of `dir`, if any.
    pub fn load(dir: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(dir.join(COMMIT_FILE))
            .map_err(|e| Error::Format(format!("no tier commit in {}: {e}", dir.display())))?;
        let doc = Json::parse(&text).map_err(Error::Format)?;
        Self::from_json(&doc)
    }

    /// Is `dir` a committed checkpoint directory?
    pub fn is_committed(dir: &Path) -> bool {
        Self::load(dir).is_ok()
    }

    /// Full verification: re-read every data block and compare CRCs.
    pub fn verify(&self, dir: &Path) -> Result<()> {
        for f in &self.files {
            let bytes = std::fs::read(dir.join(&f.path))?;
            if bytes.len() as u64 != f.len {
                return Err(Error::Integrity(format!(
                    "{}: length {} != {}",
                    f.path,
                    bytes.len(),
                    f.len
                )));
            }
            let crc = crc32fast::hash(&bytes);
            if crc != f.crc {
                return Err(Error::Integrity(format!(
                    "{}: crc {crc:08x} != {:08x}",
                    f.path, f.crc
                )));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("ckptio-tman-{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn scan_commit_load_roundtrip() {
        let dir = tmp("rt");
        std::fs::write(dir.join("a.bin"), b"hello").unwrap();
        std::fs::create_dir_all(dir.join("sub")).unwrap();
        std::fs::write(dir.join("sub/b.bin"), b"world!").unwrap();
        let m = TierManifest::from_dir(42, &dir).unwrap();
        assert_eq!(m.files.len(), 2);
        assert_eq!(m.payload_bytes(), 11);
        assert!(!TierManifest::is_committed(&dir));
        m.commit(&dir).unwrap();
        assert!(TierManifest::is_committed(&dir));
        let back = TierManifest::load(&dir).unwrap();
        assert_eq!(back, m);
        back.verify(&dir).unwrap();
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn origin_roundtrips_and_is_optional() {
        let dir = tmp("origin");
        std::fs::write(dir.join("a.bin"), b"data").unwrap();
        let m = TierManifest::from_dir(3, &dir)
            .unwrap()
            .with_origin(Some("device".into()));
        m.commit(&dir).unwrap();
        let back = TierManifest::load(&dir).unwrap();
        assert_eq!(back.origin.as_deref(), Some("device"));
        // A manifest without the field (older format) loads as None.
        let m2 = TierManifest::from_dir(3, &dir).unwrap();
        assert_eq!(m2.origin, None);
        m2.commit(&dir).unwrap();
        assert_eq!(TierManifest::load(&dir).unwrap().origin, None);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn replica_of_roundtrips_and_is_optional() {
        let dir = tmp("replof");
        std::fs::write(dir.join("a.bin"), b"data").unwrap();
        let m = TierManifest::from_dir(9, &dir)
            .unwrap()
            .with_replica_of(Some(3));
        m.commit(&dir).unwrap();
        let back = TierManifest::load(&dir).unwrap();
        assert_eq!(back.replica_of, Some(3));
        assert_eq!(back, m);
        // A manifest without the field loads as None.
        let m2 = TierManifest::from_dir(9, &dir).unwrap();
        assert_eq!(m2.replica_of, None);
        m2.commit(&dir).unwrap();
        assert_eq!(TierManifest::load(&dir).unwrap().replica_of, None);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn epoch_roundtrips_and_is_optional() {
        let dir = tmp("epoch");
        std::fs::write(dir.join("a.bin"), b"data").unwrap();
        let m = TierManifest::from_dir(4, &dir)
            .unwrap()
            .with_epoch(Some("epoch-000007".into()))
            .with_replica_of(Some(2));
        m.commit(&dir).unwrap();
        let back = TierManifest::load(&dir).unwrap();
        assert_eq!(back.epoch.as_deref(), Some("epoch-000007"));
        assert_eq!(back.replica_of, Some(2));
        assert_eq!(back, m);
        // A manifest without the field (older format) loads as None.
        let m2 = TierManifest::from_dir(4, &dir).unwrap();
        assert_eq!(m2.epoch, None);
        m2.commit(&dir).unwrap();
        assert_eq!(TierManifest::load(&dir).unwrap().epoch, None);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn commit_refuses_missing_data() {
        let dir = tmp("missing");
        std::fs::write(dir.join("a.bin"), b"data").unwrap();
        let m = TierManifest::from_dir(1, &dir).unwrap();
        std::fs::remove_file(dir.join("a.bin")).unwrap();
        let err = m.commit(&dir).unwrap_err();
        assert!(err.to_string().contains("commit before data"), "{err}");
        assert!(!TierManifest::is_committed(&dir));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn commit_refuses_truncated_data() {
        let dir = tmp("trunc");
        std::fs::write(dir.join("a.bin"), vec![7u8; 1000]).unwrap();
        let m = TierManifest::from_dir(1, &dir).unwrap();
        std::fs::write(dir.join("a.bin"), b"x").unwrap();
        assert!(m.commit(&dir).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn verify_detects_corruption() {
        let dir = tmp("corrupt");
        std::fs::write(dir.join("a.bin"), vec![1u8; 64]).unwrap();
        let m = TierManifest::from_dir(1, &dir).unwrap();
        m.commit(&dir).unwrap();
        std::fs::write(dir.join("a.bin"), vec![2u8; 64]).unwrap();
        let err = TierManifest::load(&dir).unwrap().verify(&dir).unwrap_err();
        assert!(err.to_string().contains("crc"), "{err}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn scan_skips_markers_and_temps() {
        let dir = tmp("skip");
        std::fs::write(dir.join("a.bin"), b"a").unwrap();
        std::fs::write(dir.join(COMMIT_FILE), b"{}").unwrap();
        std::fs::write(dir.join("junk.tmp"), b"t").unwrap();
        let m = TierManifest::from_dir(1, &dir).unwrap();
        assert_eq!(m.files.len(), 1);
        assert_eq!(m.files[0].path, "a.bin");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn empty_dir_is_an_error() {
        let dir = tmp("empty");
        assert!(TierManifest::from_dir(1, &dir).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
