//! The end-to-end training driver.
//!
//! Ties the three layers together: the PJRT runtime executes the
//! AOT-lowered JAX/Pallas train step; every k steps the driver exports
//! the real parameter/momentum state and checkpoints it through the
//! baseline engine's [`CheckpointStore`] (io_uring + O_DIRECT on real
//! files); at the end it restores and verifies the weights bit-exactly.
//! `examples/train_checkpoint.rs` drives this for the ~100M model and
//! logs the loss curve recorded in EXPERIMENTS.md.

use std::path::{Path, PathBuf};

use crate::ckpt::lean::{self, Lean};
use crate::ckpt::store::{CheckpointStore, RankData, SaveReport};
use crate::ckpt::Aggregation;
use crate::error::{Error, Result};
use crate::runtime::ModelRuntime;
use crate::util::prng::Xoshiro256;
use crate::util::timer::Stopwatch;

/// Training-run configuration.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub variant: String,
    pub steps: u64,
    /// Checkpoint every k steps (0 = never).
    pub ckpt_every: u64,
    pub ckpt_dir: PathBuf,
    pub aggregation: Aggregation,
    pub seed: u64,
    /// Restore at the end and verify bit-exactness.
    pub verify_restore: bool,
    /// Reuse one batch every step (clearer learning signal in short
    /// smoke runs; long runs sample fresh batches).
    pub fixed_batch: bool,
}

impl TrainConfig {
    pub fn new(variant: &str, steps: u64, ckpt_dir: impl Into<PathBuf>) -> Self {
        Self {
            variant: variant.to_string(),
            steps,
            ckpt_every: 50,
            ckpt_dir: ckpt_dir.into(),
            aggregation: Aggregation::FilePerProcess,
            seed: 42,
            verify_restore: true,
            fixed_batch: false,
        }
    }
}

/// Run outcome.
#[derive(Debug, Clone)]
pub struct TrainReport {
    /// (step, loss) samples, one per step.
    pub losses: Vec<(u64, f32)>,
    pub checkpoints: Vec<SaveReport>,
    pub restore_verified: bool,
    pub train_seconds: f64,
    pub ckpt_seconds: f64,
}

impl TrainReport {
    pub fn final_loss(&self) -> f32 {
        self.losses.last().map(|&(_, l)| l).unwrap_or(f32::NAN)
    }
    pub fn initial_loss(&self) -> f32 {
        self.losses.first().map(|&(_, l)| l).unwrap_or(f32::NAN)
    }
}

/// Execute a training run with checkpointing.
pub fn run(artifacts_dir: &Path, cfg: &TrainConfig) -> Result<TrainReport> {
    let rt = ModelRuntime::load(artifacts_dir, &cfg.variant)?;
    let mut state = rt.init_state()?;
    let mut rng = Xoshiro256::seeded(cfg.seed);
    let store = CheckpointStore::new(&cfg.ckpt_dir).with_aggregation(cfg.aggregation);

    let mut losses = Vec::with_capacity(cfg.steps as usize);
    let mut checkpoints = Vec::new();
    let mut train_s = 0.0;
    let mut ckpt_s = 0.0;
    let mut last_export: Option<Vec<(String, Vec<u8>)>> = None;
    #[allow(unused_assignments)]
    let mut fresh_slot: Option<(
        xla::PjRtBuffer,
        xla::Literal,
        xla::PjRtBuffer,
        xla::Literal,
    )> = None;

    let fixed = if cfg.fixed_batch {
        let (tok, tgt) = rt.synthetic_batch(&mut rng);
        Some((rt.token_buffer(&tok)?, rt.token_buffer(&tgt)?))
    } else {
        None
    };
    for step in 0..cfg.steps {
        let (tok_buf, tgt_buf, _keep) = match &fixed {
            Some(((tb, _), (gb, _))) => (tb, gb, None),
            None => {
                let (tok, tgt) = rt.synthetic_batch(&mut rng);
                let (tb, tk) = rt.token_buffer(&tok)?;
                let (gb, gk) = rt.token_buffer(&tgt)?;
                // Park the freshly-built buffers so references live long
                // enough; stored in an Option dropped at loop end.
                fresh_slot = Some((tb, tk, gb, gk));
                let f = fresh_slot.as_ref().unwrap();
                (&f.0, &f.2, Some(()))
            }
        };
        let sw = Stopwatch::start();
        state = rt.train_step(state, tok_buf, tgt_buf)?;
        train_s += sw.elapsed_secs();
        losses.push((step, state.last_loss));

        if cfg.ckpt_every > 0 && (step + 1) % cfg.ckpt_every == 0 {
            let sw = Stopwatch::start();
            let blobs = rt.export_params(&state)?;
            let data = RankData {
                rank: 0,
                tensors: blobs.clone(),
                lean: training_lean(step + 1, &cfg.variant, state.last_loss),
            };
            let rep = store.save(&[data])?;
            ckpt_s += sw.elapsed_secs();
            checkpoints.push(rep);
            last_export = Some(blobs);
        }
    }

    // Restore + verify.
    let mut restore_verified = false;
    if cfg.verify_restore && !checkpoints.is_empty() {
        let loaded = store.load()?;
        let rank0 = loaded
            .into_iter()
            .find(|d| d.rank == 0)
            .ok_or_else(|| Error::Integrity("restore: rank 0 missing".into()))?;
        let expected = last_export.expect("checkpointed at least once");
        if rank0.tensors.len() != expected.len() {
            return Err(Error::Integrity(format!(
                "restore: {} blobs != {} expected",
                rank0.tensors.len(),
                expected.len()
            )));
        }
        for ((n1, b1), (n2, b2)) in rank0.tensors.iter().zip(&expected) {
            if n1 != n2 || b1 != b2 {
                return Err(Error::Integrity(format!(
                    "restore: blob {n1} differs from checkpointed {n2}"
                )));
            }
        }
        // Rebuild a state from the restored bytes and run one step to
        // prove the restored weights are usable.
        let restored_step = rank0
            .lean
            .get("step")
            .and_then(|v| match v {
                Lean::Int(i) => Some(*i as u64),
                _ => None,
            })
            .unwrap_or(0);
        let restored = rt.import_params(&rank0.tensors, restored_step)?;
        let (tok, tgt) = rt.synthetic_batch(&mut rng);
        let (tok_buf, _k1) = rt.token_buffer(&tok)?;
        let (tgt_buf, _k2) = rt.token_buffer(&tgt)?;
        let after = rt.train_step(restored, &tok_buf, &tgt_buf)?;
        if !after.last_loss.is_finite() {
            return Err(Error::Integrity("restored state diverged".into()));
        }
        restore_verified = true;
    }

    Ok(TrainReport {
        losses,
        checkpoints,
        restore_verified,
        train_seconds: train_s,
        ckpt_seconds: ckpt_s,
    })
}

/// The lean object checkpointed alongside the tensors.
pub fn training_lean(step: u64, variant: &str, loss: f32) -> Lean {
    let mut l = lean::training_state(step, 3e-4, variant);
    l.set("loss", Lean::Float(loss as f64));
    l
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn tiny_end_to_end_with_checkpoints() {
        let dir = artifacts_dir();
        if !dir.join("model_tiny.manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let ckpt_dir =
            std::env::temp_dir().join(format!("ckptio-train-{}", std::process::id()));
        let cfg = TrainConfig {
            ckpt_every: 4,
            steps: 10,
            fixed_batch: true,
            ..TrainConfig::new("tiny", 10, &ckpt_dir)
        };
        let rep = run(&dir, &cfg).unwrap();
        assert_eq!(rep.losses.len(), 10);
        assert_eq!(rep.checkpoints.len(), 2);
        assert!(rep.restore_verified);
        assert!(
            rep.final_loss() < rep.initial_loss(),
            "loss {} -> {}",
            rep.initial_loss(),
            rep.final_loss()
        );
        std::fs::remove_dir_all(&ckpt_dir).ok();
    }
}
