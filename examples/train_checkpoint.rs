//! End-to-end validation: train the ~100M-parameter transformer (JAX +
//! Pallas kernels, AOT-lowered to HLO, executed from Rust via PJRT) and
//! checkpoint real weights through the io_uring baseline engine every k
//! steps, then restore and verify bit-exactness.
//!
//!     make artifacts
//!     cargo run --release --example train_checkpoint -- [steps] [ckpt_every] [variant]
//!
//! Defaults: 300 steps, checkpoint every 50, variant 100m. The loss
//! curve and checkpoint throughputs are recorded in EXPERIMENTS.md.

use ckptio::train::{self, TrainConfig};
use ckptio::util::bytes::fmt_rate;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps: u64 = args.first().and_then(|s| s.parse().ok()).unwrap_or(300);
    let ckpt_every: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(50);
    let variant = args.get(2).cloned().unwrap_or_else(|| "100m".to_string());

    let artifacts = std::path::PathBuf::from("artifacts");
    if !artifacts
        .join(format!("model_{variant}.manifest.json"))
        .exists()
    {
        return Err("artifacts missing — run `make artifacts` first".into());
    }
    let ckpt_dir = std::env::temp_dir().join("ckptio-train-e2e");

    eprintln!("== training {variant} for {steps} steps, checkpoint every {ckpt_every} ==");
    let cfg = TrainConfig {
        ckpt_every,
        ..TrainConfig::new(&variant, steps, &ckpt_dir)
    };
    let rep = train::run(&artifacts, &cfg)?;

    println!("step,loss");
    for (s, l) in &rep.losses {
        if s % 10 == 0 || *s + 1 == steps {
            println!("{s},{l:.4}");
        }
    }
    println!("#");
    println!(
        "# loss: {:.4} -> {:.4} over {} steps",
        rep.initial_loss(),
        rep.final_loss(),
        steps
    );
    println!(
        "# train time: {:.1}s ({:.3}s/step)",
        rep.train_seconds,
        rep.train_seconds / steps as f64
    );
    for (i, c) in rep.checkpoints.iter().enumerate() {
        println!(
            "# checkpoint {}: {} files, {} MiB payload, {:.3}s ({})",
            i,
            c.files,
            c.payload_bytes >> 20,
            c.seconds,
            fmt_rate(c.payload_bytes as f64 / c.seconds),
        );
    }
    println!(
        "# restore verified bit-exact: {}",
        if rep.restore_verified { "YES" } else { "no" }
    );
    std::fs::remove_dir_all(&ckpt_dir).ok();
    Ok(())
}
