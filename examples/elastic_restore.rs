//! Elastic restore in ~70 lines: save a checkpoint at 8 ranks
//! (tp=2, pp=2, dp=2) through the tiered cascade, then resume at 4
//! ranks (tp=2, pp=2, dp=1) — a dp-shrink after losing half the fleet
//! — with the extent planner coalescing the resharded reads.
//!
//!     cargo run --release --example elastic_restore

use ckptio::ckpt::lean;
use ckptio::exec::real::BackendKind;
use ckptio::reshard::elastic::{assemble_logical, shard_data};
use ckptio::reshard::{ReadPlanner, ShardIndex};
use ckptio::tier::{TierCascade, TierPolicy, TierSpec};
use ckptio::util::bytes::fmt_bytes;
use ckptio::util::prng::Xoshiro256;
use ckptio::workload::Parallelism;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let base = std::env::temp_dir().join("ckptio-elastic-example");
    let _ = std::fs::remove_dir_all(&base);

    // The logical model: a few dp-replicated weights plus dp-partitioned
    // optimizer state (`optim.*` — the reshard naming convention).
    let mut rng = Xoshiro256::seeded(42);
    let logical: Vec<(String, Vec<u8>)> = (0..12)
        .map(|i| {
            let mut b = vec![0u8; 512 * 1024 + 4096 * i];
            rng.fill_bytes(&mut b);
            let name = if i % 3 == 0 {
                format!("optim.state.{i:02}")
            } else {
                format!("layers.{i:02}.weight")
            };
            (name, b)
        })
        .collect();
    let volume: u64 = logical.iter().map(|(_, b)| b.len() as u64).sum();

    // Save at 8 ranks through the cascade (burst buffer → "PFS").
    let source = Parallelism::new(2, 2, 2);
    let cascade = TierCascade::new(
        vec![
            TierSpec::new("bb", base.join("bb")).with_backend(BackendKind::Posix),
            TierSpec::new("pfs", base.join("pfs")).with_backend(BackendKind::Posix),
        ],
        TierPolicy::WriteBack { drain_depth: 2 },
    )?;
    let data = shard_data(&logical, source, &lean::training_state(100, 3e-4, "elastic"));
    cascade.save(100, &data)?;
    cascade.flush()?;
    println!(
        "saved {} at tp={} pp={} dp={} ({} ranks)",
        fmt_bytes(volume),
        source.tp,
        source.pp,
        source.dp,
        source.world()
    );

    // Half the fleet is gone: resume at 4 ranks. The planner knobs are
    // documented in rust/configs/polaris.toml under [reshard]; load
    // them when the config is around, else take the defaults.
    let target = Parallelism::new(2, 2, 1);
    let planner = std::fs::read_to_string("configs/polaris.toml")
        .ok()
        .and_then(|text| ReadPlanner::from_toml(&text).ok())
        .unwrap_or_default();
    // What the read side would have done naively, vs the coalesced plan.
    let bb_dir = base.join("bb").join("step_00000100");
    let index = ShardIndex::from_store(&bb_dir)?;
    let naive: usize = ReadPlanner::naive()
        .rank_plans(&index, target, 4)
        .iter()
        .map(|rp| rp.reads())
        .sum();
    let stats = planner.rank_plans(&index, target, 4);
    let coalesced: usize = stats.iter().map(|rp| rp.reads()).sum();
    let moved: u64 = stats.iter().map(|rp| rp.read_bytes).sum();
    println!(
        "read plan: {naive} naive shard reads -> {coalesced} coalesced reads \
         (gap_fill {}, {} moved)",
        fmt_bytes(planner.gap_fill),
        fmt_bytes(moved),
    );

    let (restored, tier) = cascade.restore_elastic(100, target, &planner)?;
    println!(
        "elastic restore served from {tier}: {} ranks at tp={} pp={} dp={}",
        restored.len(),
        target.tp,
        target.pp,
        target.dp
    );

    // Bit-identity at the logical-tensor level.
    let mut back = assemble_logical(&restored)?;
    back.sort_by(|a, b| a.0.cmp(&b.0));
    let mut want = logical.clone();
    want.sort_by(|a, b| a.0.cmp(&b.0));
    assert_eq!(back, want, "logical tensors must roundtrip bit-identically");
    println!("logical tensors bit-identical after the dp-shrink restore");

    // The burst-buffer copy goes away (node replacement): the slower
    // tier serves the same resharded restore.
    cascade.evict(0, 100)?;
    let (again, tier) = cascade.restore_elastic(100, target, &planner)?;
    assert_eq!(assemble_logical(&again)?.len(), back.len());
    println!("after bb eviction the restore fell back to {tier}");

    std::fs::remove_dir_all(&base)?;
    Ok(())
}
