//! Compare every checkpoint engine on the simulated Polaris testbed over
//! the paper's realistic LLM workloads — a compact version of Figures
//! 11/12/18.
//!
//!     cargo run --release --example engine_comparison -- [3b|7b|13b]

use ckptio::ckpt::Aggregation;
use ckptio::coordinator::{Coordinator, Substrate, Topology};
use ckptio::engines::{CkptEngine, DataStatesLlm, EngineCtx, TorchSave, TorchSnapshot, UringBaseline};
use ckptio::simpfs::SimParams;
use ckptio::util::bytes::{fmt_bytes, fmt_rate};
use ckptio::workload::CheckpointLayout;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let model = std::env::args().nth(1).unwrap_or_else(|| "3b".to_string());
    let layout = CheckpointLayout::paper_preset(&model)
        .ok_or_else(|| format!("unknown model {model:?}"))?;
    println!(
        "model {}: {} ranks, {} files, {}",
        layout.model,
        layout.shards.len(),
        layout.total_files(),
        fmt_bytes(layout.total_bytes())
    );

    let engines: Vec<Box<dyn CkptEngine>> = vec![
        Box::new(UringBaseline::new(Aggregation::SharedFile)),
        Box::new(DataStatesLlm::default()),
        Box::new(TorchSnapshot::default()),
        Box::new(TorchSave),
    ];

    // The paper's "ideal approach" flushes host-resident buffers; the
    // production engines run their full device-transfer pipelines.
    let ideal = Coordinator::new(
        Topology::polaris(layout.shards.len()),
        Substrate::Sim(SimParams::polaris()),
    )
    .with_ctx(EngineCtx {
        include_device_transfers: false,
        serialize_offsets: true,
        ..Default::default()
    });
    let full = Coordinator::new(
        Topology::polaris(layout.shards.len()),
        Substrate::Sim(SimParams::polaris()),
    )
    .with_ctx(EngineCtx {
        include_device_transfers: true,
        serialize_offsets: true,
        ..Default::default()
    });

    println!(
        "\n{:<24} {:>14} {:>14} {:>10}",
        "engine", "ckpt tput", "restore tput", "meta ops"
    );
    let mut base_w = 0.0;
    for (i, e) in engines.iter().enumerate() {
        let coord = if i == 0 { &ideal } else { &full };
        let w = coord.checkpoint(e.as_ref(), &layout.shards)?;
        let r = coord.restore(e.as_ref(), &layout.shards)?;
        if i == 0 {
            base_w = w.write_throughput();
        }
        println!(
            "{:<24} {:>14} {:>14} {:>10}   ({:.1}x vs baseline writes)",
            e.name(),
            fmt_rate(w.write_throughput()),
            fmt_rate(r.read_throughput()),
            w.meta_ops,
            base_w / w.write_throughput().max(1.0),
        );
    }
    Ok(())
}
