//! The hierarchical checkpoint cascade in ~60 lines: stage checkpoints
//! into a local burst-buffer tier, drain them to the "PFS" tier on
//! background workers, survive an eviction, and prefetch on restore.
//!
//!     cargo run --release --example tiered_checkpoint

use ckptio::ckpt::lean::Lean;
use ckptio::ckpt::store::RankData;
use ckptio::exec::real::BackendKind;
use ckptio::tier::{DeviceStage, RestorePrefetcher, Tier, TierCascade, TierPolicy, TierSpec};
use ckptio::util::bytes::fmt_rate;
use ckptio::util::prng::Xoshiro256;

fn rank_data(step: u64) -> Vec<RankData> {
    let mut rng = Xoshiro256::seeded(step);
    (0..2)
        .map(|rank| {
            let mut b = vec![0u8; 8 << 20];
            rng.fill_bytes(&mut b);
            let mut lean = Lean::dict();
            lean.set("step", Lean::Int(step as i64));
            RankData {
                rank,
                tensors: vec![(format!("layer.{rank}.weight"), b)],
                lean,
            }
        })
        .collect()
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let base = std::env::temp_dir().join("ckptio-tiered-example");
    let _ = std::fs::remove_dir_all(&base);

    // Device tier 0 (HBM capacity model, newest-2 pinned) in front of a
    // capacity-limited burst buffer and an unbounded "PFS".
    let cascade = TierCascade::new(
        vec![
            TierSpec::new("burst-buffer", base.join("bb")).with_capacity(64 << 20),
            TierSpec::new("pfs", base.join("pfs")).with_backend(BackendKind::Posix),
        ],
        TierPolicy::WriteBack { drain_depth: 2 },
    )?
    .with_device_stage(DeviceStage::new(48 << 20, 2));

    // Checkpoint every "iteration"; only the burst-buffer write blocks
    // (the D2H drain is PCIe-rate-modeled, reported as virtual time).
    for step in 1..=4u64 {
        let rep = cascade.save(step, &rank_data(step))?;
        println!(
            "step {step}: {} MiB blocked {:.3}s ({}){} d2h {:.4}s",
            rep.payload_bytes >> 20,
            rep.blocking_s,
            fmt_rate(rep.payload_bytes as f64 / rep.blocking_s.max(1e-9)),
            if rep.device_resident { ", HBM-pinned," } else { "," },
            rep.d2h_s,
        );
    }
    cascade.flush()?; // all drains durable on the PFS tier
    println!(
        "device holds steps {:?}; burst buffer holds {:?}; pfs holds {:?}",
        cascade.device_steps(),
        cascade.resident_steps(0),
        cascade.resident_steps(1)
    );

    // The newest step restores straight from HBM; no storage I/O.
    let (step, data, tier) = cascade.restore_latest()?;
    assert_eq!(data[0].tensors, rank_data(step)[0].tensors);
    assert_eq!(tier, Tier::Device);
    println!("restored step {step} from tier {tier} bit-exactly ✓");

    // Evict it locally; the cascade falls back to the PFS copy and the
    // prefetcher pulls the next steps back into the burst buffer.
    for s in cascade.resident_steps(0) {
        cascade.evict(0, s)?;
    }
    let mut pf = RestorePrefetcher::new(&cascade, 1..=4u64);
    while let Some(res) = pf.next() {
        let (s, data, tier) = res?;
        assert_eq!(data[0].tensors, rank_data(s)[0].tensors);
        println!("replayed step {s} from tier {tier}");
        cascade.flush()?; // let the overlap finish for the demo
    }

    std::fs::remove_dir_all(&base).ok();
    Ok(())
}
