//! Sweep the three aggregation strategies across process counts and
//! checkpoint sizes on the simulated Polaris testbed — the shape of the
//! paper's Figures 5–8 — and on real local storage for comparison.
//!
//!     cargo run --release --example aggregation_sweep

use ckptio::ckpt::Aggregation;
use ckptio::coordinator::{Coordinator, Substrate, Topology};
use ckptio::engines::{EngineCtx, UringBaseline};
use ckptio::simpfs::SimParams;
use ckptio::util::bytes::{fmt_bytes, fmt_rate, GIB, MIB};
use ckptio::workload::synthetic::Synthetic;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    println!("== scaling ranks (8 GiB per rank, simulated Polaris) ==");
    println!(
        "{:<6} {:>16} {:>16} {:>16}",
        "ranks", "file-per-tensor", "file-per-proc", "shared-file"
    );
    for ranks in [1usize, 4, 8, 16] {
        let shards = Synthetic::new(ranks, 8 * GIB).shards();
        let coord = Coordinator::new(
            Topology::polaris(ranks),
            Substrate::Sim(SimParams::polaris()),
        );
        let mut row = format!("{ranks:<6}");
        for agg in Aggregation::all() {
            let e = UringBaseline::new(agg);
            let rep = coord.checkpoint(&e, &shards)?;
            row += &format!(" {:>16}", fmt_rate(rep.write_throughput()));
        }
        println!("{row}");
    }

    println!("\n== scaling size (4 ranks, simulated Polaris) ==");
    println!(
        "{:<10} {:>16} {:>16} {:>16}",
        "size/rank", "file-per-tensor", "file-per-proc", "shared-file"
    );
    for size in [128 * MIB, 512 * MIB, 2 * GIB, 8 * GIB] {
        let shards = Synthetic::new(4, size).shards();
        let coord =
            Coordinator::new(Topology::polaris(4), Substrate::Sim(SimParams::polaris()));
        let mut row = format!("{:<10}", fmt_bytes(size));
        for agg in Aggregation::all() {
            let e = UringBaseline::new(agg);
            let rep = coord.checkpoint(&e, &shards)?;
            row += &format!(" {:>16}", fmt_rate(rep.write_throughput()));
        }
        println!("{row}");
    }

    println!("\n== real local disk (2 ranks x 64 MiB, io_uring + O_DIRECT) ==");
    let dir = std::env::temp_dir().join("ckptio-agg-sweep");
    for agg in Aggregation::all() {
        let shards = Synthetic::new(2, 64 * MIB).shards();
        let coord = Coordinator::new(
            Topology::polaris(2),
            Substrate::Real { root: dir.clone() },
        )
        .with_ctx(EngineCtx::default());
        let e = UringBaseline::new(agg);
        let rep = coord.checkpoint(&e, &shards)?;
        println!(
            "{:<18} write={} ({:.3}s)",
            agg.name(),
            fmt_rate(rep.write_throughput()),
            rep.makespan
        );
        std::fs::remove_dir_all(&dir).ok();
    }
    Ok(())
}
