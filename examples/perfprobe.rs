//! Perf probe: measures the three L3 hot paths (store save throughput,
//! PJRT train-step latency, parameter export) — the measurement tool
//! behind EXPERIMENTS.md §Perf. Run with the artifacts built:
//!
//!     cargo run --release --example perfprobe
//!
use ckptio::ckpt::lean;
use ckptio::ckpt::store::{CheckpointStore, RankData};
use ckptio::runtime::ModelRuntime;
use ckptio::util::prng::Xoshiro256;
use std::time::Instant;
fn main() {
    // L3: store save throughput (3 reps, 256 MiB).
    let root = std::env::temp_dir().join("ckptio-perf");
    let _ = std::fs::remove_dir_all(&root);
    let mut rng = Xoshiro256::seeded(1);
    let tensors: Vec<(String, Vec<u8>)> = (0..8).map(|i| {
        let mut b = vec![0u8; 32 << 20];
        rng.fill_bytes(&mut b);
        (format!("t{i}"), b)
    }).collect();
    let store = CheckpointStore::new(&root);
    for rep in 0..3 {
        let t = Instant::now();
        let r = store.save(&[RankData { rank: 0, tensors: tensors.clone(), lean: lean::training_state(1, 0.1, "p") }]).unwrap();
        println!("save rep{rep}: {:.3}s ({:.0} MB/s) [exec {:.3}s]", t.elapsed().as_secs_f64(),
            256.0 / t.elapsed().as_secs_f64(), r.seconds);
    }
    let _ = std::fs::remove_dir_all(&root);

    // L3/L2 boundary: export_params + train steps on tiny.
    let dir = std::path::PathBuf::from("artifacts");
    let rt = ModelRuntime::load(&dir, "tiny").unwrap();
    let mut state = rt.init_state().unwrap();
    let (tok, tgt) = rt.synthetic_batch(&mut rng);
    let (tok, _k1) = rt.token_buffer(&tok).unwrap();
    let (tgt, _k2) = rt.token_buffer(&tgt).unwrap();
    // warmup
    for _ in 0..3 { state = rt.train_step(state, &tok, &tgt).unwrap(); }
    let t = Instant::now();
    let n = 40;
    for _ in 0..n { state = rt.train_step(state, &tok, &tgt).unwrap(); }
    println!("train_step tiny: {:.2} ms/step", t.elapsed().as_secs_f64()*1e3/n as f64);
    let t = Instant::now();
    for _ in 0..10 { let _ = rt.export_params(&state).unwrap(); }
    println!("export_params tiny: {:.2} ms", t.elapsed().as_secs_f64()*1e3/10.0);
}
