//! Quickstart: checkpoint and restore a set of tensors through the
//! io_uring baseline engine on real files, in ~30 lines.
//!
//!     cargo run --release --example quickstart

use ckptio::ckpt::lean::{Lean};
use ckptio::ckpt::store::{CheckpointStore, RankData};
use ckptio::ckpt::Aggregation;
use ckptio::util::bytes::fmt_rate;
use ckptio::util::prng::Xoshiro256;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let dir = std::env::temp_dir().join("ckptio-quickstart");

    // 1. Some "model state": four 16 MiB tensors of random bytes.
    let mut rng = Xoshiro256::seeded(7);
    let tensors: Vec<(String, Vec<u8>)> = (0..4)
        .map(|i| {
            let mut b = vec![0u8; 16 << 20];
            rng.fill_bytes(&mut b);
            (format!("layer.{i}.weight"), b)
        })
        .collect();
    let mut lean = Lean::dict();
    lean.set("step", Lean::Int(1000));

    // 2. Save: aggregated into one file per rank, written via io_uring
    //    with O_DIRECT, CRC-protected metadata header in-band.
    let store = CheckpointStore::new(&dir).with_aggregation(Aggregation::FilePerProcess);
    let rep = store.save(&[RankData {
        rank: 0,
        tensors: tensors.clone(),
        lean,
    }])?;
    println!(
        "checkpointed {} MiB in {:.3}s ({})",
        rep.payload_bytes >> 20,
        rep.seconds,
        fmt_rate(rep.payload_bytes as f64 / rep.seconds),
    );

    // 3. Load it back — bit-exact, CRC-verified.
    let restored = store.load()?;
    assert_eq!(restored[0].tensors, tensors);
    println!("restored {} tensors bit-exactly ✓", restored[0].tensors.len());

    std::fs::remove_dir_all(&dir).ok();
    Ok(())
}
