//! Inference-style model swapping: many model variants checkpointed on
//! disk, restored in and out of a capacity-limited device tier — the
//! paper's motivation for high-velocity restore (serving models that do
//! not all fit in GPU memory).
//!
//!     cargo run --release --example restore_swap

use ckptio::ckpt::lean::Lean;
use ckptio::ckpt::store::{CheckpointStore, RankData};
use ckptio::coordinator::gpu::DeviceTier;
use ckptio::util::bytes::fmt_rate;
use ckptio::util::prng::Xoshiro256;
use ckptio::util::timer::Stopwatch;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let root = std::env::temp_dir().join("ckptio-swap");
    let n_models = 6usize;
    let model_bytes = 24usize << 20; // 24 MiB per "model"
    let mut rng = Xoshiro256::seeded(11);

    // Persist n model variants, each via its own store directory.
    let mut stores = Vec::new();
    for m in 0..n_models {
        let dir = root.join(format!("model_{m}"));
        let store = CheckpointStore::new(&dir);
        let mut weights = vec![0u8; model_bytes];
        rng.fill_bytes(&mut weights);
        let mut lean = Lean::dict();
        lean.set("model_id", Lean::Int(m as i64));
        store.save(&[RankData {
            rank: 0,
            tensors: vec![("weights".into(), weights)],
            lean,
        }])?;
        stores.push(store);
    }
    println!("persisted {n_models} model variants of {} MiB each", model_bytes >> 20);

    // A device that fits only 3 models: serve a request trace that
    // cycles through all of them, swapping via restore.
    let mut device = DeviceTier::new((3 * model_bytes) as u64 + 1024);
    let mut hits = 0u32;
    let mut swaps = 0u32;
    let mut swap_time = 0.0;
    let mut swap_bytes = 0u64;
    let trace: Vec<usize> = (0..30).map(|_| rng.index(n_models)).collect();
    for &m in &trace {
        let name = format!("model_{m}");
        if device.get(&name).is_some() {
            hits += 1;
            continue;
        }
        // Evict LRU-ish (first listed) until it fits, then restore.
        while device.capacity() - device.used() < model_bytes as u64 {
            let victim = device.names()[0].to_string();
            device.evict(&victim);
        }
        let sw = Stopwatch::start();
        let data = stores[m].load()?;
        let weights = data[0].tensors[0].1.clone();
        swap_time += sw.elapsed_secs();
        swap_bytes += weights.len() as u64;
        device.put(&name, weights)?;
        swaps += 1;
    }
    println!(
        "trace of {} requests: {hits} resident hits, {swaps} swaps, swap read {}",
        trace.len(),
        fmt_rate(swap_bytes as f64 / swap_time),
    );
    std::fs::remove_dir_all(&root).ok();
    Ok(())
}
