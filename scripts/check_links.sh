#!/usr/bin/env bash
# Intra-repo markdown link checker (no dependencies beyond coreutils +
# grep/sed). Scans tracked *.md files for inline links, resolves
# relative targets against the linking file's directory, and fails if
# any target is missing. External (http/https/mailto) links and
# pure-anchor links are skipped; a fragment on a relative link is
# stripped before the existence check.
set -euo pipefail

cd "$(dirname "$0")/.."

fail=0
checked=0

# Tracked + untracked-but-not-ignored markdown, so stray editor
# backups (ignored) don't break CI but brand-new docs are covered.
files="$(git ls-files -c -o --exclude-standard '*.md')"

for f in $files; do
  dir="$(dirname "$f")"
  # Inline links/images: capture the (...) target of [text](target).
  # One match per line via grep -o; multi-link lines emit one each.
  targets="$(grep -oE '\]\([^)]+\)' "$f" | sed -E 's/^\]\(//; s/\)$//' || true)"
  [ -n "$targets" ] || continue
  while IFS= read -r t; do
    case "$t" in
      http://*|https://*|mailto:*|\#*) continue ;;
    esac
    # Strip an optional fragment and surrounding whitespace.
    path="${t%%#*}"
    path="$(printf '%s' "$path" | sed -E 's/^[[:space:]]+//; s/[[:space:]]+$//')"
    [ -n "$path" ] || continue
    checked=$((checked + 1))
    if [ ! -e "$dir/$path" ]; then
      echo "BROKEN: $f -> $t (resolved: $dir/$path)" >&2
      fail=1
    fi
  done <<EOF
$targets
EOF
done

echo "checked $checked relative links across $(printf '%s\n' $files | wc -l) markdown files"
exit $fail
